//! The unified serving API: the [`Engine`] trait every backend fidelity
//! implements, plus its typed companions — [`Capabilities`] (static
//! introspection), [`Telemetry`] (cumulative energy/time/steps/utilization
//! counters) and the non-blocking [`Engine::submit`]/[`Engine::poll`] pair.
//!
//! `Engine` subsumes the old `coordinator::Backend` trait (batched
//! inference + `max_batch`) so the coordinator, the report exhibits and
//! future multi-fabric shards all drive backends through one surface.

use super::error::EngineError;
use super::spec::BackendKind;
use crate::device::ReprogramPlan;
use crate::nn::packed::PackedBatch;
use crate::nn::BinaryLayer;

/// A batch in flight through submit → dispatch → complete. The packed
/// form is the hot path: an `Arc`-shared [`PackedBatch`] moves as an
/// index range over one shared bit buffer, so handing it to a shard
/// thread (or rerouting it off a dead one) clones a pointer, never the
/// images. The scalar form remains for ragged batches — engines own the
/// shape policy, so the dispatcher must not reject them early.
#[derive(Clone, Debug)]
pub enum Batch {
    /// Legacy scalar images (ragged batches land here).
    Bools(Vec<Vec<bool>>),
    /// `Arc`-shared packed buffer + index range (zero-copy dispatch).
    Packed(PackedBatch),
}

impl Batch {
    /// Pack when uniform, fall back to the scalar form when ragged.
    pub fn from_images(images: Vec<Vec<bool>>) -> Self {
        match PackedBatch::from_images(&images) {
            Some(p) => Batch::Packed(p),
            None => Batch::Bools(images),
        }
    }

    /// Images in the batch.
    pub fn len(&self) -> usize {
        match self {
            Batch::Bools(imgs) => imgs.len(),
            Batch::Packed(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize scalar images (allocates for the packed form).
    pub fn to_images(&self) -> Vec<Vec<bool>> {
        match self {
            Batch::Bools(imgs) => imgs.clone(),
            Batch::Packed(p) => p.to_images(),
        }
    }
}

/// Output of a batched inference.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceResult {
    /// Hardware thresholded bits, `[image][neuron]`.
    pub bits: Vec<Vec<bool>>,
    /// Functional class prediction per image (count-space argmax, realized
    /// on hardware by a θ-sweep of `V_DD`).
    pub classes: Vec<usize>,
    /// Simulated array busy time for the batch \[s\] (0 for XLA).
    pub sim_time: f64,
    /// Simulated energy for the batch \[J\] (0 for XLA).
    pub energy: f64,
    /// Computational steps consumed.
    pub steps: u64,
}

/// What an engine *is*: static introspection a scheduler can plan with
/// before submitting any work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// Backend fidelity this engine realizes.
    pub kind: BackendKind,
    /// Input bits per image.
    pub n_in: usize,
    /// Output neurons per image.
    pub n_out: usize,
    /// Largest batch one `infer_batch` call accepts.
    pub max_batch: usize,
    /// Physical subarrays backing the engine.
    pub nodes: usize,
    /// Weight tiles placed on those subarrays.
    pub tiles: usize,
    /// Independent shards behind `submit` (1 for the plain engines). A
    /// scheduler can keep this many batches in flight productively.
    pub shards: usize,
    /// Whether `InferenceResult::energy`/`sim_time` carry physical values
    /// (the XLA golden model reports zeros).
    pub reports_energy: bool,
    /// Whether batches overlap internally (image-level pipelining).
    pub pipelined: bool,
}

/// Cumulative typed telemetry, updated by every successful `infer_batch`
/// (and therefore by `submit`). Counters accumulate since construction;
/// `utilization` is the per-subarray busy fraction of the *most recent*
/// batch (single-subarray engines report an empty vector).
#[derive(Clone, Debug, PartialEq)]
pub struct Telemetry {
    pub batches: u64,
    pub images: u64,
    /// TMVM computational steps executed.
    pub steps: u64,
    /// Simulated array busy time \[s\].
    pub sim_time: f64,
    /// Total simulated energy \[J\].
    pub energy: f64,
    /// Compute (TMVM step) share of `energy` \[J\] (fabric engines).
    pub compute_energy: f64,
    /// Interlink/switch share of `energy` \[J\] (fabric engines).
    pub link_energy: f64,
    /// Makespan in computational-step quanta (fabric engines).
    pub cycles: u64,
    /// Interlink hop-transfers (fabric engines).
    pub link_transfers: u64,
    /// Interlink line-hops of traffic (fabric engines).
    pub link_lines: u64,
    /// Completed in-place weight swaps ([`Engine::swap_network`]).
    pub swaps: u64,
    /// Simulated time spent programming weights during swaps \[s\]
    /// (kept separate from `sim_time`: programming is the array's storage
    /// role, not Table II compute accounting).
    pub program_time: f64,
    /// Energy spent programming weights during swaps \[J\] (pulses plus
    /// weight-distribution traffic; separate from `energy` for the same
    /// reason).
    pub program_energy: f64,
    /// Cumulative SET+RESET pulses programmed into this engine's cells
    /// (swaps, plus spawn programming for elastic shards) — the endurance
    /// wear the autoscaler budgets against.
    pub wear_pulses: u64,
    /// Energy premium of serving an N-ary multibit workload \[J\]: the
    /// per-dot-product surcharge of the configured scheme (paper Table
    /// III, [`multibit_tmvm_cost`](crate::array::multibit::multibit_tmvm_cost))
    /// times the logical dot products served. Already included in
    /// `energy`; broken out so operators can see what the resolution
    /// upgrade costs. 0 on binary workloads.
    pub multibit_energy: f64,
    /// Per-subarray busy fraction of the most recent batch.
    pub utilization: Vec<f64>,
    /// Worst (minimum) noise margin across the engine's arrays, for
    /// engines that model parasitics — `+∞` when the engine runs at ideal
    /// fidelity and margins are not evaluated (so min-merging across a
    /// mixed fleet surfaces exactly the parasitic shards' margins).
    pub margin_min: f64,
}

/// Hand-written (not derived) so the no-margin-reported state is `+∞`,
/// the identity of the min-merge — a derived `0.0` would read as "margin
/// fully closed" on every ideal engine.
impl Default for Telemetry {
    fn default() -> Self {
        Self {
            batches: 0,
            images: 0,
            steps: 0,
            sim_time: 0.0,
            energy: 0.0,
            compute_energy: 0.0,
            link_energy: 0.0,
            cycles: 0,
            link_transfers: 0,
            link_lines: 0,
            swaps: 0,
            program_time: 0.0,
            program_energy: 0.0,
            wear_pulses: 0,
            multibit_energy: 0.0,
            utilization: Vec::new(),
            margin_min: f64::INFINITY,
        }
    }
}

impl Telemetry {
    /// Fold one batch result into the counters.
    pub(crate) fn record(&mut self, res: &InferenceResult) {
        self.batches += 1;
        self.images += res.bits.len() as u64;
        self.steps += res.steps;
        self.sim_time += res.sim_time;
        self.energy += res.energy;
    }

    /// Mean energy per served image \[J\].
    pub fn energy_per_image(&self) -> f64 {
        if self.images > 0 {
            self.energy / self.images as f64
        } else {
            0.0
        }
    }

    /// Mean of the per-subarray busy fractions (0 when not reported).
    pub fn mean_utilization(&self) -> f64 {
        if self.utilization.is_empty() {
            0.0
        } else {
            self.utilization.iter().sum::<f64>() / self.utilization.len() as f64
        }
    }

    /// Peak per-subarray busy fraction (0 when not reported).
    pub fn max_utilization(&self) -> f64 {
        self.utilization.iter().cloned().fold(0.0, f64::max)
    }
}

/// Handle for a submitted batch, redeemed via [`Engine::poll`].
pub type Ticket = u64;

/// What an in-place weight swap cost ([`Engine::swap_network`]): the
/// executed pulse plan plus the simulated time/energy the rewrite
/// occupied the array(s).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SwapReport {
    /// `0 → 1` SET pulses executed.
    pub set_pulses: u64,
    /// `1 → 0` RESET pulses executed.
    pub reset_pulses: u64,
    /// Cells that flipped.
    pub cells_changed: u64,
    /// All weight cells covered by the rewrite.
    pub cells_total: u64,
    /// Simulated time the array(s) were busy programming \[s\].
    pub time: f64,
    /// Programming energy: pulses + weight-distribution traffic \[J\].
    pub energy: f64,
    /// Engine shards the swap walked (1 for plain engines).
    pub shards: usize,
}

impl SwapReport {
    /// Fold another shard's report into this one (a rolling swap walks
    /// shards one at a time, so times add).
    pub fn merge(&mut self, other: &Self) {
        self.set_pulses += other.set_pulses;
        self.reset_pulses += other.reset_pulses;
        self.cells_changed += other.cells_changed;
        self.cells_total += other.cells_total;
        self.time += other.time;
        self.energy += other.energy;
        self.shards += other.shards;
    }
}

impl From<&ReprogramPlan> for SwapReport {
    fn from(plan: &ReprogramPlan) -> Self {
        Self {
            set_pulses: plan.set_pulses,
            reset_pulses: plan.reset_pulses,
            cells_changed: plan.cells_changed(),
            cells_total: plan.cells_total(),
            time: plan.time,
            energy: plan.energy,
            shards: 1,
        }
    }
}

/// Point-in-time load an autoscaling policy plans with: how many shards
/// are serving, how many are parked, and how much work is waiting on or
/// inside the engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScaleLoad {
    /// Shards currently in the dispatch pool.
    pub serving: usize,
    /// Shards drained and parked (retired hardware, wear history kept).
    pub parked: usize,
    /// Images parked in the engine-level queue (not yet on any shard).
    pub queued_images: usize,
    /// Images submitted to shards and not yet drained.
    pub in_flight_images: usize,
}

impl ScaleLoad {
    /// Backlog (queued + in-flight images) per serving shard — the
    /// queue-depth signal the watermarks compare against.
    pub fn backlog_per_shard(&self) -> f64 {
        if self.serving == 0 {
            return 0.0;
        }
        (self.queued_images + self.in_flight_images) as f64 / self.serving as f64
    }
}

/// What kind of elastic lifecycle event happened.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleEventKind {
    /// A shard entered the dispatch pool: a parked slot reprogrammed back
    /// (`fresh: false`) or a brand-new slot pulsed its first full weight
    /// image into fresh cells (`fresh: true`).
    Spawn { fresh: bool },
    /// A serving shard drained and parked.
    Retire,
    /// A parked shard was skipped for spawn because reprogramming it
    /// would exceed its pulse-endurance budget.
    Veto,
}

impl ScaleEventKind {
    pub fn name(self) -> &'static str {
        match self {
            Self::Spawn { fresh: true } => "spawn-fresh",
            Self::Spawn { fresh: false } => "spawn-rejoin",
            Self::Retire => "retire",
            Self::Veto => "veto",
        }
    }
}

/// One completed elastic lifecycle event, with the programming cost it
/// carried (zero for retires and no-op rejoins).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleEvent {
    pub kind: ScaleEventKind,
    /// Shard slot the event happened to.
    pub shard: usize,
    /// SET+RESET pulses the event programmed (projected pulses for a
    /// `Veto`).
    pub pulses: u64,
    /// Programming energy \[J\].
    pub energy: f64,
    /// Serialized programming time \[s\].
    pub time: f64,
    /// Serving shards after the event took effect.
    pub serving_after: usize,
}

/// What a canary-carrying fleet observed: a parasitic-fidelity shard
/// shadows a sample of live traffic behind the ideal shards, and the
/// engine compares the two fidelities' *electrical* outputs
/// ([`InferenceResult::bits`] — the classes are functional and identical
/// by construction) batch by batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CanaryReport {
    /// Images mirrored through the canary shard.
    pub sampled_images: u64,
    /// Mirrored batches whose primary/canary pair both completed and were
    /// compared.
    pub compared_batches: u64,
    /// Sampled images whose electrical bits diverged between the ideal
    /// primary and the parasitic canary.
    pub divergent_images: u64,
    /// Worst noise margin the canary's arrays report (`+∞` until the
    /// canary shard publishes telemetry).
    pub margin_min: f64,
}

impl Default for CanaryReport {
    fn default() -> Self {
        Self {
            sampled_images: 0,
            compared_batches: 0,
            divergent_images: 0,
            margin_min: f64::INFINITY,
        }
    }
}

impl CanaryReport {
    /// Divergent fraction of the sampled images (0 when nothing sampled).
    pub fn divergence_rate(&self) -> f64 {
        if self.sampled_images == 0 {
            0.0
        } else {
            self.divergent_images as f64 / self.sampled_images as f64
        }
    }
}

/// A batched binary-NN inference engine at some fidelity.
///
/// Not `Send`: PJRT handles are thread-affine, so the coordinator
/// constructs each engine *inside* its worker thread via a
/// [`BackendFactory`].
pub trait Engine {
    /// Infer a batch of images (each `n_in` bits), blocking until done.
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult>;

    /// Largest batch the engine can take at once.
    fn max_batch(&self) -> usize;

    /// Static introspection: what this engine is and can do.
    fn capabilities(&self) -> Capabilities;

    /// Cumulative counters since construction (see [`Telemetry`]).
    fn telemetry(&self) -> Telemetry;

    /// Per-shard telemetry. Plain engines are their own single shard; a
    /// [`ShardedEngine`](super::sharded::ShardedEngine) reports one entry
    /// per shard so schedulers and metrics can see load balance.
    fn shard_telemetry(&self) -> Vec<Telemetry> {
        vec![self.telemetry()]
    }

    /// Non-blocking enqueue: accept a batch, return a [`Ticket`] redeemed
    /// via [`poll`](Engine::poll). The in-process simulation engines
    /// complete the batch before returning (the simulation is synchronous
    /// host-side work), so their tickets are immediately redeemable — that
    /// [`Completions`]-backed behavior is the trivial adapter that lets
    /// the coordinator's scheduler loop drive blocking backends through
    /// the same surface as genuinely asynchronous ones
    /// ([`ShardedEngine`](super::sharded::ShardedEngine), whose batches
    /// complete later on shard worker threads).
    fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket>;

    /// [`infer_batch`](Engine::infer_batch) over an `Arc`-shared packed
    /// batch — the zero-copy hot path. Engines with a packed kernel
    /// (simulation, fabric, sharded) override this to skip the scalar
    /// materialization; the default unpacks once and delegates, so every
    /// backend accepts packed input.
    fn infer_packed(&mut self, batch: &PackedBatch) -> crate::Result<InferenceResult> {
        self.infer_batch(&batch.to_images())
    }

    /// [`submit`](Engine::submit) over an `Arc`-shared packed batch:
    /// dispatch moves the `(Arc, range)` pair, not cloned images. The
    /// default unpacks once and delegates.
    fn submit_packed(&mut self, batch: PackedBatch) -> crate::Result<Ticket> {
        self.submit(batch.to_images())
    }

    /// Redeem a ticket: `Ok(Some(..))` once the batch is done (at most
    /// once per ticket), `Ok(None)` while still in flight. Errors are
    /// typed and never block or panic: [`EngineError::Empty`] when nothing
    /// was ever submitted, [`EngineError::UnknownTicket`] for tickets
    /// never issued or already collected.
    fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>>;

    /// Reprogram the resident network to `target` in place, blocking
    /// until the rewrite completes. The contract is atomicity: every
    /// inference reflects wholly-old or wholly-new weights, never a torn
    /// mix — plain engines validate-then-mutate, a sharded engine drains
    /// and reprograms shards one at a time
    /// ([`ShardedEngine`](super::sharded::ShardedEngine) rolling swap).
    /// Backends that cannot rewrite weights (the AOT-compiled XLA golden
    /// model) fail with the typed [`EngineError::SwapUnsupported`].
    fn swap_network(&mut self, target: Vec<BinaryLayer>) -> crate::Result<SwapReport> {
        let _ = target;
        Err(EngineError::SwapUnsupported {
            kind: self.capabilities().kind.name(),
        }
        .into())
    }

    /// Non-blocking swap start. `Ok(Some(report))` means the swap
    /// completed synchronously (the in-process engines rewrite inline,
    /// mirroring their `submit`); `Ok(None)` means a rolling swap is now
    /// in progress — redeem it via [`poll_swap`](Engine::poll_swap) while
    /// continuing to `submit`/`poll` traffic.
    fn begin_swap(&mut self, target: Vec<BinaryLayer>) -> crate::Result<Option<SwapReport>> {
        self.swap_network(target).map(Some)
    }

    /// Redeem an in-progress rolling swap: `Ok(Some(report))` once every
    /// shard has rejoined (at most once per swap), `Ok(None)` while
    /// shards are still draining/reprogramming. The typed
    /// [`EngineError::NoSwap`] when no swap is active.
    fn poll_swap(&mut self) -> crate::Result<Option<SwapReport>> {
        Err(EngineError::NoSwap.into())
    }

    /// Load snapshot for autoscaling decisions. Plain engines are one
    /// always-serving shard with no engine-side backlog visibility.
    fn scale_load(&self) -> ScaleLoad {
        ScaleLoad {
            serving: 1,
            ..ScaleLoad::default()
        }
    }

    /// Bring one more shard into the dispatch pool: reprogram a parked
    /// slot whose pulse-endurance budget admits the delta, or construct a
    /// fresh slot and pulse the full weight image into it. Non-blocking —
    /// returns the shard index once the operation is underway; the shard
    /// walks `Spawning → Programming → Rejoining → Serving` while traffic
    /// keeps flowing. Typed failures: [`EngineError::ScaleUnsupported`]
    /// (no elastic template), [`EngineError::ScaleBusy`],
    /// [`EngineError::PulseBudget`].
    fn spawn_shard(&mut self) -> crate::Result<usize> {
        Err(EngineError::ScaleUnsupported {
            kind: self.capabilities().kind.name(),
        }
        .into())
    }

    /// Take one shard out of the dispatch pool: it drains (`Serving →
    /// Draining → Parked`) while its completed tickets stay redeemable.
    /// Non-blocking; picks the most-worn serving shard so rest goes to
    /// the cells that need it. Typed failures mirror
    /// [`spawn_shard`](Engine::spawn_shard), plus
    /// [`EngineError::LastServingShard`].
    fn retire_shard(&mut self) -> crate::Result<usize> {
        Err(EngineError::ScaleUnsupported {
            kind: self.capabilities().kind.name(),
        }
        .into())
    }

    /// Drain the elastic lifecycle events completed since the last call
    /// (spawns, retires, budget vetoes) — the coordinator folds these
    /// into its metrics. Plain engines never produce any.
    fn take_scale_events(&mut self) -> Vec<ScaleEvent> {
        Vec::new()
    }

    /// What the fleet's canary observed so far, for engines carrying one
    /// (a [`ShardedEngine`](super::sharded::ShardedEngine) built with a
    /// canary slot). `None` for every engine without a canary — the
    /// coordinator only surfaces canary telemetry when it exists.
    fn canary_report(&self) -> Option<CanaryReport> {
        None
    }

    /// Whether no elastic lifecycle walk (spawn/retire) is currently in
    /// flight. Always true for engines that cannot scale; schedulers use
    /// it to let an in-progress walk land (and publish its event) before
    /// shutting down.
    fn scale_settled(&self) -> bool {
        true
    }

    /// Whether the engine can still serve. The in-process engines never
    /// go unhealthy; a [`RemoteBackend`](crate::net::RemoteBackend) turns
    /// false once its connection is lost (timeouts, resets, protocol
    /// violations), at which point a sharded scheduler stops routing to
    /// the shard and fails its in-flight tickets with typed
    /// [`EngineError::Remote`] errors.
    fn healthy(&self) -> bool {
        true
    }

    /// Park the caller until the engine may have made progress (a
    /// completion or lifecycle event arrived) or `timeout` elapsed.
    /// Schedulers call this instead of spinning on `poll` — an
    /// asynchronous engine blocks on its completion channel (waking the
    /// moment a shard reports), while the synchronous engines, which
    /// complete everything inside `submit`, simply sleep out the timeout.
    fn wait_event(&mut self, timeout: std::time::Duration) {
        std::thread::sleep(timeout);
    }
}

/// Constructs an engine on the worker thread that will own it.
pub type BackendFactory = Box<dyn FnOnce() -> crate::Result<Box<dyn Engine>> + Send + 'static>;

/// Completion buffer shared by the synchronous engines' `submit`/`poll`
/// implementations: issues monotonically increasing tickets and hands each
/// finished result out exactly once.
#[derive(Debug, Default)]
pub struct Completions {
    issued: Ticket,
    done: Vec<(Ticket, InferenceResult)>,
}

impl Completions {
    /// Stash a finished result, returning its ticket.
    pub fn push(&mut self, res: InferenceResult) -> Ticket {
        self.issued += 1;
        self.done.push((self.issued, res));
        self.issued
    }

    /// Redeem `ticket` (exactly once). Polling before anything was ever
    /// submitted is the typed [`EngineError::Empty`]; an issued-but-gone
    /// (or never-issued) ticket is [`EngineError::UnknownTicket`].
    pub fn take(&mut self, ticket: Ticket) -> Result<InferenceResult, EngineError> {
        match self.done.iter().position(|(t, _)| *t == ticket) {
            Some(i) => Ok(self.done.remove(i).1),
            None if self.issued == 0 => Err(EngineError::Empty),
            None => Err(EngineError::UnknownTicket(ticket)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(n: usize) -> InferenceResult {
        InferenceResult {
            bits: vec![vec![true]; n],
            classes: vec![0; n],
            sim_time: 1.0,
            energy: 2.0,
            steps: 3,
        }
    }

    #[test]
    fn telemetry_accumulates_batches() {
        let mut t = Telemetry::default();
        t.record(&result(4));
        t.record(&result(2));
        assert_eq!(t.batches, 2);
        assert_eq!(t.images, 6);
        assert_eq!(t.steps, 6);
        assert!((t.sim_time - 2.0).abs() < 1e-12);
        assert!((t.energy_per_image() - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(Telemetry::default().energy_per_image(), 0.0);
    }

    #[test]
    fn utilization_summaries() {
        let t = Telemetry {
            utilization: vec![0.2, 0.6, 0.4],
            ..Telemetry::default()
        };
        assert!((t.mean_utilization() - 0.4).abs() < 1e-12);
        assert!((t.max_utilization() - 0.6).abs() < 1e-12);
        assert_eq!(Telemetry::default().mean_utilization(), 0.0);
        assert_eq!(Telemetry::default().max_utilization(), 0.0);
    }

    #[test]
    fn swap_report_merges_and_lifts_from_plans() {
        let plan = ReprogramPlan {
            set_pulses: 3,
            reset_pulses: 2,
            unchanged: 5,
            time: 1e-6,
            energy: 2e-12,
        };
        let mut a = SwapReport::from(&plan);
        assert_eq!(a.cells_changed, 5);
        assert_eq!(a.cells_total, 10);
        assert_eq!(a.shards, 1);
        let b = SwapReport::from(&plan);
        a.merge(&b);
        assert_eq!(a.set_pulses, 6);
        assert_eq!(a.shards, 2);
        assert!((a.time - 2e-6).abs() < 1e-18);
        assert!((a.energy - 4e-12).abs() < 1e-24);
    }

    #[test]
    fn scale_load_backlog_is_per_serving_shard() {
        let load = ScaleLoad {
            serving: 2,
            parked: 1,
            queued_images: 6,
            in_flight_images: 10,
        };
        assert!((load.backlog_per_shard() - 8.0).abs() < 1e-12);
        assert_eq!(
            ScaleLoad {
                serving: 0,
                ..ScaleLoad::default()
            }
            .backlog_per_shard(),
            0.0,
            "no serving shards: no meaningful backlog signal"
        );
    }

    #[test]
    fn scale_event_kinds_have_names() {
        assert_eq!(ScaleEventKind::Spawn { fresh: true }.name(), "spawn-fresh");
        assert_eq!(ScaleEventKind::Spawn { fresh: false }.name(), "spawn-rejoin");
        assert_eq!(ScaleEventKind::Retire.name(), "retire");
        assert_eq!(ScaleEventKind::Veto.name(), "veto");
    }

    #[test]
    fn completions_hand_out_each_ticket_once() {
        let mut c = Completions::default();
        let t1 = c.push(result(1));
        let t2 = c.push(result(2));
        assert_ne!(t1, t2);
        assert_eq!(c.take(t2).unwrap().bits.len(), 2);
        assert_eq!(c.take(t1).unwrap().bits.len(), 1);
        assert_eq!(c.take(t1).unwrap_err(), EngineError::UnknownTicket(t1));
        assert_eq!(c.take(99).unwrap_err(), EngineError::UnknownTicket(99));
    }

    #[test]
    fn polling_before_any_submit_is_the_typed_empty_error() {
        let mut c = Completions::default();
        assert_eq!(c.take(1).unwrap_err(), EngineError::Empty);
        let t = c.push(result(1));
        c.take(t).unwrap();
        // once something was submitted, a bad ticket is UnknownTicket
        assert_eq!(c.take(t).unwrap_err(), EngineError::UnknownTicket(t));
    }
}

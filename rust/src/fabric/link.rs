//! Interlink channels of the fabric: directed nearest-neighbour links
//! between adjacent subarrays (the BL-to-BL / BL-to-WLT switch fabrics of
//! Fig. 6, generalized to a grid), plus a dedicated host-injection spine.
//!
//! Transfers are routed dimension-ordered (columns first, then rows) and
//! reserve each hop FIFO: a hop starts no earlier than the link frees up,
//! so contention shows up as latency instead of being silently ignored.
//! Per-hop energy uses the same switch-loss expression as
//! [`LinkedPair::tmvm_into`](crate::scaling::interlink::LinkedPair):
//! `E = I_total² · R_switch · t_SET`.

use super::event::{secs_to_ticks, Time};
use super::placement::FabricConfig;
use std::collections::HashMap;

/// One directed channel (between adjacent subarrays, or from the host
/// spine into a subarray).
#[derive(Clone, Debug)]
pub struct Interlink {
    pub from: usize,
    pub to: usize,
    /// The channel is reserved up to this simulated time.
    pub busy_until: Time,
    /// Completed transfers over this channel.
    pub transfers: u64,
    /// Bit lines carried by this channel (one "line" = one row's partial
    /// result or one activation bit lane).
    pub lines: u64,
    /// Switch losses booked on this channel \[J\].
    pub energy: f64,
}

impl Interlink {
    fn new(from: usize, to: usize) -> Self {
        Self {
            from,
            to,
            busy_until: 0,
            transfers: 0,
            lines: 0,
            energy: 0.0,
        }
    }

    /// Reserve this channel for one transfer of `dur` ticks starting no
    /// earlier than `ready`; returns the arrival time.
    fn reserve(&mut self, ready: Time, dur: Time, lines: u64, energy: f64) -> Time {
        let start = ready.max(self.busy_until);
        let end = start + dur;
        self.busy_until = end;
        self.transfers += 1;
        self.lines += lines;
        self.energy += energy;
        end
    }
}

/// Aggregate interlink traffic of a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkTraffic {
    /// Grid interlink hop-transfers (one transfer crossing three hops
    /// counts three).
    pub transfers: u64,
    /// Line-hops: bit lines moved × hops crossed (the per-hop sum, the
    /// traffic a link-level power model integrates — not distinct lines).
    pub lines: u64,
    /// Grid interlink switch energy \[J\].
    pub energy: f64,
    /// Host-spine injections.
    pub input_transfers: u64,
    /// Host-spine energy \[J\].
    pub input_energy: f64,
}

/// The grid of interlinks plus the host-injection spine.
#[derive(Clone, Debug)]
pub struct LinkFabric {
    grid_rows: usize,
    grid_cols: usize,
    t_hop: Time,
    r_switch: f64,
    t_set: f64,
    links: Vec<Interlink>,
    /// `(from, to)` → index into `links` for adjacent node pairs.
    edges: HashMap<(usize, usize), usize>,
    /// One injection channel per node, fed by the host spine.
    input_ports: Vec<Interlink>,
    /// Injection latency per node: `t_hop · (1 + manhattan((0,0), node))`.
    input_latency: Vec<Time>,
}

impl LinkFabric {
    pub fn new(cfg: &FabricConfig) -> Self {
        let (gr, gc) = (cfg.grid_rows, cfg.grid_cols);
        let t_hop = secs_to_ticks(cfg.t_hop).max(1);
        let mut links = Vec::new();
        let mut edges = HashMap::new();
        let add = |links: &mut Vec<Interlink>,
                       edges: &mut HashMap<(usize, usize), usize>,
                       a: usize,
                       b: usize| {
            edges.insert((a, b), links.len());
            links.push(Interlink::new(a, b));
            edges.insert((b, a), links.len());
            links.push(Interlink::new(b, a));
        };
        for r in 0..gr {
            for c in 0..gc {
                let n = r * gc + c;
                if c + 1 < gc {
                    add(&mut links, &mut edges, n, n + 1);
                }
                if r + 1 < gr {
                    add(&mut links, &mut edges, n, n + gc);
                }
            }
        }
        let mut input_ports = Vec::with_capacity(gr * gc);
        let mut input_latency = Vec::with_capacity(gr * gc);
        for n in 0..gr * gc {
            let (r, c) = (n / gc, n % gc);
            input_ports.push(Interlink::new(usize::MAX, n));
            input_latency.push(t_hop * (1 + r + c) as Time);
        }
        Self {
            grid_rows: gr,
            grid_cols: gc,
            t_hop,
            r_switch: cfg.r_switch,
            t_set: cfg.device.t_set,
            links,
            edges,
            input_ports,
            input_latency,
        }
    }

    /// Dimension-ordered route (columns first, then rows); empty when
    /// `from == to`.
    pub fn route(&self, from: usize, to: usize) -> Vec<usize> {
        let gc = self.grid_cols;
        let (mut r, mut c) = (from / gc, from % gc);
        let (tr, tc) = (to / gc, to % gc);
        debug_assert!(r < self.grid_rows && tr < self.grid_rows);
        let mut hops = Vec::new();
        while c != tc {
            let nc = if tc > c { c + 1 } else { c - 1 };
            hops.push(self.edges[&(r * gc + c, r * gc + nc)]);
            c = nc;
        }
        while r != tr {
            let nr = if tr > r { r + 1 } else { r - 1 };
            hops.push(self.edges[&(r * gc + c, nr * gc + c)]);
            r = nr;
        }
        hops
    }

    /// Reserve a transfer of `lines` bit lines carrying total current
    /// `i_total` from node `from` to node `to`, ready at `ready`.
    /// Returns the arrival time (== `ready` when `from == to`).
    pub fn transfer(&mut self, ready: Time, from: usize, to: usize, lines: u64, i_total: f64) -> Time {
        let hop_energy = i_total * i_total * self.r_switch * self.t_set;
        let mut t = ready;
        for hop in self.route(from, to) {
            t = self.links[hop].reserve(t, self.t_hop, lines, hop_energy);
        }
        t
    }

    /// Inject an input slice from the host spine into `node`.
    pub fn transfer_input(&mut self, ready: Time, node: usize, lines: u64, i_total: f64) -> Time {
        let energy = i_total * i_total * self.r_switch * self.t_set;
        let dur = self.input_latency[node];
        self.input_ports[node].reserve(ready, dur, lines, energy)
    }

    /// Aggregate traffic counters.
    pub fn totals(&self) -> LinkTraffic {
        let mut t = LinkTraffic::default();
        for l in &self.links {
            t.transfers += l.transfers;
            t.lines += l.lines;
            t.energy += l.energy;
        }
        for p in &self.input_ports {
            t.input_transfers += p.transfers;
            t.input_energy += p.energy;
        }
        t
    }

    /// Per-link view (for reports/tests).
    pub fn links(&self) -> &[Interlink] {
        &self.links
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(gr: usize, gc: usize) -> LinkFabric {
        LinkFabric::new(&FabricConfig::new(gr, gc, 8, 8))
    }

    #[test]
    fn grid_has_all_directed_neighbour_links() {
        let f = fabric(2, 3);
        // horizontal: 2 rows × 2 gaps, vertical: 1 gap × 3 cols — ×2 directions
        assert_eq!(f.links.len(), (2 * 2 + 3) * 2);
        assert!(f.edges.contains_key(&(0, 1)) && f.edges.contains_key(&(1, 0)));
        assert!(f.edges.contains_key(&(2, 5)) && f.edges.contains_key(&(5, 2)));
        assert!(!f.edges.contains_key(&(0, 5)), "no diagonal links");
    }

    #[test]
    fn route_is_dimension_ordered_manhattan() {
        let f = fabric(3, 4);
        // node 1 = (0,1), node 11 = (2,3): 2 col hops then 2 row hops
        let hops = f.route(1, 11);
        assert_eq!(hops.len(), 4);
        let first = &f.links[hops[0]];
        assert_eq!((first.from, first.to), (1, 2));
        let last = &f.links[hops[3]];
        assert_eq!((last.from, last.to), (7, 11));
        assert!(f.route(5, 5).is_empty());
    }

    #[test]
    fn transfers_serialize_on_shared_links() {
        let mut f = fabric(1, 3);
        let hop = f.t_hop;
        let a1 = f.transfer(0, 0, 2, 4, 1e-4);
        assert_eq!(a1, 2 * hop);
        // second transfer over the same first link queues behind it
        let a2 = f.transfer(0, 0, 1, 4, 1e-4);
        assert_eq!(a2, 2 * hop);
        let a3 = f.transfer(0, 0, 2, 4, 1e-4);
        assert_eq!(a3, 4 * hop, "queues behind both earlier reservations");
        let tot = f.totals();
        assert_eq!(tot.transfers, 5);
        assert_eq!(tot.lines, 5 * 4);
        assert!(tot.energy > 0.0);
    }

    #[test]
    fn same_node_transfer_is_free_and_instant() {
        let mut f = fabric(2, 2);
        assert_eq!(f.transfer(123, 3, 3, 9, 1e-3), 123);
        let tot = f.totals();
        assert_eq!(tot.transfers, 0);
        assert_eq!(tot.energy, 0.0);
    }

    #[test]
    fn host_spine_latency_grows_with_distance() {
        let mut f = fabric(2, 2);
        let hop = f.t_hop;
        assert_eq!(f.transfer_input(0, 0, 1, 1e-4), hop);
        assert_eq!(f.transfer_input(0, 3, 1, 1e-4), 3 * hop);
        // port occupancy serializes per node
        assert_eq!(f.transfer_input(0, 0, 1, 1e-4), 2 * hop);
        let tot = f.totals();
        assert_eq!(tot.input_transfers, 3);
        assert!(tot.input_energy > 0.0);
    }
}

//! Length-prefixed, versioned wire protocol for driving one shard over a
//! socket — the messages that already drive a shard in process (infer
//! orders, rolling-swap orders, telemetry reads) made portable so a
//! [`RemoteBackend`](super::RemoteBackend) can speak them to an `xpoint
//! shard-host` on another machine.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! u32 len | u8 version | u8 tag | payload (len - 2 bytes)
//! ```
//!
//! `len` counts everything after itself and is capped at [`MAX_FRAME`]
//! *before* any allocation, so a hostile or corrupt peer cannot make the
//! decoder balloon memory. Every decode path returns a typed
//! [`WireError`] — never a panic — on truncated frames, oversized
//! lengths, version mismatches, unknown tags or inconsistent payloads.
//! Bit vectors (images, weight rows) travel bit-packed (LSB-first), and
//! floats travel as IEEE-754 bits so a roundtrip is bit-exact.
//!
//! **Version 2** adds [`TAG_INFER_PACKED`]: a uniform-width infer batch
//! ships as one contiguous LSB-first bit buffer (`id | n_images | width |
//! bits`) instead of per-image `len + bytes` rows — no per-image length
//! words, no per-image byte padding, ~8× smaller for small images.
//! Decoders accept [`MIN_PROTOCOL_VERSION`]`..=`[`PROTOCOL_VERSION`];
//! the packed tag inside a v1 frame is a typed [`WireError::Malformed`]
//! (v1 never defined it). Ragged, empty and zero-width batches keep the
//! legacy [`TAG_INFER`] encoding — engines own the shape policy.

use std::io::Read;

use crate::engine::{BackendKind, Capabilities, InferenceResult, SwapReport, Telemetry};
use crate::nn::BinaryLayer;

/// Protocol version carried in every frame we encode.
///
/// **Version 3** appends `multibit_energy` to every telemetry payload
/// (the Table III N-ary workload surcharge); v1/v2 telemetry decodes
/// with the field defaulted to 0.
pub const PROTOCOL_VERSION: u8 = 3;

/// Oldest protocol version this decoder still accepts (v1 frames differ
/// only by not carrying [`TAG_INFER_PACKED`]).
pub const MIN_PROTOCOL_VERSION: u8 = 1;

/// Hard cap on one frame's body (version + tag + payload) \[bytes\].
pub const MAX_FRAME: u64 = 16 * 1024 * 1024;

/// Handshake magic ("XPNT"): a [`Msg::Hello`] carrying anything else is
/// some other protocol that happened to land on our port.
pub const MAGIC: u32 = 0x5850_4e54;

/// Typed decode/transport failure. Decoding untrusted bytes can fail in
/// exactly these ways and in no case panics or over-allocates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The stream ended inside a frame (header or body).
    Truncated { needed: usize, got: usize },
    /// The announced frame length exceeds [`MAX_FRAME`].
    Oversized { len: u64, max: u64 },
    /// The peer speaks a different protocol version.
    Version { got: u8, want: u8 },
    /// The frame tag is not one we know.
    UnknownTag(u8),
    /// A [`Msg::Hello`] carried the wrong magic.
    BadMagic(u32),
    /// The payload is internally inconsistent (bad counts, bad UTF-8,
    /// trailing bytes, out-of-range values).
    Malformed(String),
    /// The underlying socket read/write failed.
    Io(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            Self::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes exceeds the {max}-byte cap")
            }
            Self::Version { got, want } => {
                write!(f, "protocol version mismatch: peer speaks v{got}, we speak v{want}")
            }
            Self::UnknownTag(t) => write!(f, "unknown frame tag {t}"),
            Self::BadMagic(m) => {
                write!(f, "bad handshake magic {m:#010x} (expected {MAGIC:#010x})")
            }
            Self::Malformed(d) => write!(f, "malformed payload: {d}"),
            Self::Io(d) => write!(f, "socket i/o failed: {d}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One protocol message. Requests flow client → host, the matching `*Ok`
/// (or [`Msg::Err`] for an application-level failure) flows back.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Client handshake: carries [`MAGIC`].
    Hello { magic: u32 },
    /// Host handshake reply: what the served shard is, plus its telemetry
    /// at connect time (the client baselines its deltas against it).
    HelloOk { caps: Capabilities, telemetry: Telemetry },
    /// Infer a batch; `id` is echoed in the reply so a client can detect
    /// a desynchronized stream.
    Infer { id: u64, images: Vec<Vec<bool>> },
    InferOk { id: u64, result: InferenceResult, telemetry: Telemetry },
    /// Reprogram the resident network in place (a rolling swap's
    /// per-shard order).
    Swap { target: Vec<BinaryLayer> },
    SwapOk { report: SwapReport, telemetry: Telemetry },
    /// Read the host's cumulative telemetry.
    Telemetry,
    TelemetryOk { telemetry: Telemetry },
    /// Application-level failure (the request was understood but the
    /// engine refused it); the connection stays usable.
    Err { detail: String },
    /// Ask the host process to stop serving and exit.
    Shutdown,
    ShutdownOk,
}

const TAG_HELLO: u8 = 1;
const TAG_HELLO_OK: u8 = 2;
const TAG_INFER: u8 = 3;
const TAG_INFER_OK: u8 = 4;
const TAG_SWAP: u8 = 5;
const TAG_SWAP_OK: u8 = 6;
const TAG_TELEMETRY: u8 = 7;
const TAG_TELEMETRY_OK: u8 = 8;
const TAG_ERR: u8 = 9;
const TAG_SHUTDOWN: u8 = 10;
const TAG_SHUTDOWN_OK: u8 = 11;
/// v2: a uniform-width [`Msg::Infer`] batch as one contiguous bit buffer.
pub const TAG_INFER_PACKED: u8 = 12;

// ------------------------------------------------------------- encoding

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_usize(out, s.len());
    out.extend_from_slice(s.as_bytes());
}

/// Bit-pack `bits` LSB-first into `ceil(n/8)` bytes (count *not* written —
/// callers that need it write it first).
fn put_bits(out: &mut Vec<u8>, bits: &[bool]) {
    let mut byte = 0u8;
    for (i, &b) in bits.iter().enumerate() {
        if b {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if bits.len() % 8 != 0 {
        out.push(byte);
    }
}

fn put_bool_rows(out: &mut Vec<u8>, rows: &[Vec<bool>]) {
    put_usize(out, rows.len());
    for row in rows {
        put_usize(out, row.len());
        put_bits(out, row);
    }
}

/// Width shared by every image when the batch can take the packed
/// encoding: non-empty, rectangular, width ≥ 1. Anything else stays on
/// the legacy per-row encoding.
fn uniform_width(images: &[Vec<bool>]) -> Option<usize> {
    let w = images.first()?.len();
    if w == 0 || images.iter().any(|img| img.len() != w) {
        return None;
    }
    Some(w)
}

/// Bit-pack every image contiguously LSB-first with **no per-image
/// padding** — the [`TAG_INFER_PACKED`] payload body ([`put_bits`] pads
/// each call to a byte; this must not).
fn put_packed_bits(out: &mut Vec<u8>, images: &[Vec<bool>]) {
    let mut byte = 0u8;
    let mut n = 0usize;
    for img in images {
        for &b in img {
            if b {
                byte |= 1 << (n % 8);
            }
            n += 1;
            if n % 8 == 0 {
                out.push(byte);
                byte = 0;
            }
        }
    }
    if n % 8 != 0 {
        out.push(byte);
    }
}

fn put_telemetry(out: &mut Vec<u8>, t: &Telemetry) {
    put_u64(out, t.batches);
    put_u64(out, t.images);
    put_u64(out, t.steps);
    put_f64(out, t.sim_time);
    put_f64(out, t.energy);
    put_f64(out, t.compute_energy);
    put_f64(out, t.link_energy);
    put_u64(out, t.cycles);
    put_u64(out, t.link_transfers);
    put_u64(out, t.link_lines);
    put_u64(out, t.swaps);
    put_f64(out, t.program_time);
    put_f64(out, t.program_energy);
    put_u64(out, t.wear_pulses);
    put_f64(out, t.multibit_energy);
    put_usize(out, t.utilization.len());
    for &u in &t.utilization {
        put_f64(out, u);
    }
}

fn put_caps(out: &mut Vec<u8>, c: &Capabilities) {
    out.push(kind_code(c.kind));
    put_usize(out, c.n_in);
    put_usize(out, c.n_out);
    put_usize(out, c.max_batch);
    put_usize(out, c.nodes);
    put_usize(out, c.tiles);
    put_usize(out, c.shards);
    out.push(u8::from(c.reports_energy) | (u8::from(c.pipelined) << 1));
}

fn put_result(out: &mut Vec<u8>, r: &InferenceResult) {
    put_bool_rows(out, &r.bits);
    put_usize(out, r.classes.len());
    for &c in &r.classes {
        put_usize(out, c);
    }
    put_f64(out, r.sim_time);
    put_f64(out, r.energy);
    put_u64(out, r.steps);
}

fn put_swap_report(out: &mut Vec<u8>, s: &SwapReport) {
    put_u64(out, s.set_pulses);
    put_u64(out, s.reset_pulses);
    put_u64(out, s.cells_changed);
    put_u64(out, s.cells_total);
    put_f64(out, s.time);
    put_f64(out, s.energy);
    put_usize(out, s.shards);
}

fn put_layers(out: &mut Vec<u8>, layers: &[BinaryLayer]) {
    put_usize(out, layers.len());
    for l in layers {
        put_usize(out, l.n_out());
        put_usize(out, l.n_in());
        put_usize(out, l.theta);
        for row in &l.weights {
            put_bits(out, row);
        }
    }
}

fn kind_code(k: BackendKind) -> u8 {
    match k {
        BackendKind::Ideal => 0,
        BackendKind::Parasitic => 1,
        BackendKind::Fabric => 2,
        BackendKind::Xla => 3,
        BackendKind::Sharded => 4,
        BackendKind::Remote => 5,
    }
}

fn kind_from_code(c: u8) -> Result<BackendKind, WireError> {
    Ok(match c {
        0 => BackendKind::Ideal,
        1 => BackendKind::Parasitic,
        2 => BackendKind::Fabric,
        3 => BackendKind::Xla,
        4 => BackendKind::Sharded,
        5 => BackendKind::Remote,
        _ => return Err(WireError::Malformed(format!("unknown backend code {c}"))),
    })
}

// ------------------------------------------------------------- decoding

/// Bounds-checked cursor over one frame's payload. Every read verifies
/// the bytes exist before touching them, and every count is sanity-capped
/// against the bytes remaining so a forged count cannot drive a huge
/// allocation.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                needed: n,
                got: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn usize_(&mut self) -> Result<usize, WireError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| WireError::Malformed(format!("value {v} overflows usize")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read an element count whose elements each occupy at least
    /// `min_bytes` of payload; a count that could not possibly fit in the
    /// remaining bytes is rejected *before* any allocation.
    fn count(&mut self, min_bytes: usize) -> Result<usize, WireError> {
        let n = self.usize_()?;
        let fits = self.remaining() / min_bytes.max(1);
        if n > fits {
            return Err(WireError::Malformed(format!(
                "count {n} cannot fit in {} remaining bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    /// Read `n` bit-packed bits (the inverse of [`put_bits`]).
    fn bits(&mut self, n: usize) -> Result<Vec<bool>, WireError> {
        let packed = self.bytes(n.div_ceil(8))?;
        Ok((0..n).map(|i| packed[i / 8] & (1 << (i % 8)) != 0).collect())
    }

    fn bool_rows(&mut self) -> Result<Vec<Vec<bool>>, WireError> {
        let n = self.count(8)?;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let bits = self.usize_()?;
            if bits.div_ceil(8) > self.remaining() {
                return Err(WireError::Truncated {
                    needed: bits.div_ceil(8),
                    got: self.remaining(),
                });
            }
            rows.push(self.bits(bits)?);
        }
        Ok(rows)
    }

    fn str_(&mut self) -> Result<String, WireError> {
        let n = self.count(1)?;
        let bytes = self.bytes(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("string is not UTF-8".into()))
    }

    fn telemetry(&mut self, version: u8) -> Result<Telemetry, WireError> {
        let mut t = Telemetry {
            batches: self.u64()?,
            images: self.u64()?,
            steps: self.u64()?,
            sim_time: self.f64()?,
            energy: self.f64()?,
            compute_energy: self.f64()?,
            link_energy: self.f64()?,
            cycles: self.u64()?,
            link_transfers: self.u64()?,
            link_lines: self.u64()?,
            swaps: self.u64()?,
            program_time: self.f64()?,
            program_energy: self.f64()?,
            wear_pulses: self.u64()?,
            // appended by protocol v3 — older peers never booked it
            multibit_energy: if version >= 3 { self.f64()? } else { 0.0 },
            utilization: Vec::new(),
            // not carried by wire v2: a remote shard's margin telemetry
            // stays host-side, so the decoder reports the no-margin state
            // (the min-merge identity) rather than a fake closed margin
            margin_min: f64::INFINITY,
        };
        let n = self.count(8)?;
        t.utilization = (0..n).map(|_| self.f64()).collect::<Result<_, _>>()?;
        Ok(t)
    }

    fn caps(&mut self) -> Result<Capabilities, WireError> {
        let kind = kind_from_code(self.u8()?)?;
        let n_in = self.usize_()?;
        let n_out = self.usize_()?;
        let max_batch = self.usize_()?;
        let nodes = self.usize_()?;
        let tiles = self.usize_()?;
        let shards = self.usize_()?;
        let flags = self.u8()?;
        Ok(Capabilities {
            kind,
            n_in,
            n_out,
            max_batch,
            nodes,
            tiles,
            shards,
            reports_energy: flags & 1 != 0,
            pipelined: flags & 2 != 0,
        })
    }

    fn result(&mut self) -> Result<InferenceResult, WireError> {
        let bits = self.bool_rows()?;
        let n = self.count(8)?;
        let classes = (0..n).map(|_| self.usize_()).collect::<Result<_, _>>()?;
        Ok(InferenceResult {
            bits,
            classes,
            sim_time: self.f64()?,
            energy: self.f64()?,
            steps: self.u64()?,
        })
    }

    fn swap_report(&mut self) -> Result<SwapReport, WireError> {
        Ok(SwapReport {
            set_pulses: self.u64()?,
            reset_pulses: self.u64()?,
            cells_changed: self.u64()?,
            cells_total: self.u64()?,
            time: self.f64()?,
            energy: self.f64()?,
            shards: self.usize_()?,
        })
    }

    fn layers(&mut self) -> Result<Vec<BinaryLayer>, WireError> {
        let n = self.count(24)?;
        let mut layers = Vec::with_capacity(n);
        for _ in 0..n {
            let n_out = self.usize_()?;
            let n_in = self.usize_()?;
            let theta = self.usize_()?;
            // BinaryLayer::new asserts on these — validate first so a
            // hostile frame errors instead of panicking
            if n_out == 0 || n_in == 0 || theta == 0 {
                return Err(WireError::Malformed(format!(
                    "layer shape {n_out}x{n_in} theta {theta} (all must be >= 1)"
                )));
            }
            let row_bytes = n_in.div_ceil(8);
            if n_out > self.remaining() / row_bytes {
                return Err(WireError::Truncated {
                    needed: n_out * row_bytes,
                    got: self.remaining(),
                });
            }
            let weights = (0..n_out).map(|_| self.bits(n_in)).collect::<Result<_, _>>()?;
            layers.push(BinaryLayer::new(weights, theta));
        }
        Ok(layers)
    }

    /// The payload must be fully consumed — trailing bytes mean the peer
    /// and we disagree about the message shape.
    fn finish(self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

impl Msg {
    /// Short message name for diagnostics (a full `Debug` render could
    /// carry megabytes of weights).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Hello { .. } => "hello",
            Self::HelloOk { .. } => "hello-ok",
            Self::Infer { .. } => "infer",
            Self::InferOk { .. } => "infer-ok",
            Self::Swap { .. } => "swap",
            Self::SwapOk { .. } => "swap-ok",
            Self::Telemetry => "telemetry",
            Self::TelemetryOk { .. } => "telemetry-ok",
            Self::Err { .. } => "err",
            Self::Shutdown => "shutdown",
            Self::ShutdownOk => "shutdown-ok",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            Self::Hello { .. } => TAG_HELLO,
            Self::HelloOk { .. } => TAG_HELLO_OK,
            Self::Infer { .. } => TAG_INFER,
            Self::InferOk { .. } => TAG_INFER_OK,
            Self::Swap { .. } => TAG_SWAP,
            Self::SwapOk { .. } => TAG_SWAP_OK,
            Self::Telemetry => TAG_TELEMETRY,
            Self::TelemetryOk { .. } => TAG_TELEMETRY_OK,
            Self::Err { .. } => TAG_ERR,
            Self::Shutdown => TAG_SHUTDOWN,
            Self::ShutdownOk => TAG_SHUTDOWN_OK,
        }
    }

    /// Encode to a complete frame (length prefix included). Fails with
    /// [`WireError::Oversized`] if the message would exceed [`MAX_FRAME`].
    pub fn to_frame(&self) -> Result<Vec<u8>, WireError> {
        let mut out = vec![0, 0, 0, 0, PROTOCOL_VERSION, self.tag()];
        match self {
            Self::Hello { magic } => put_u32(&mut out, *magic),
            Self::HelloOk { caps, telemetry } => {
                put_caps(&mut out, caps);
                put_telemetry(&mut out, telemetry);
            }
            Self::Infer { id, images } => match uniform_width(images) {
                // hot path: one contiguous bit buffer, no per-image
                // length words or byte padding (v2 encoding)
                Some(w) => {
                    out[5] = TAG_INFER_PACKED;
                    put_u64(&mut out, *id);
                    put_usize(&mut out, images.len());
                    put_usize(&mut out, w);
                    put_packed_bits(&mut out, images);
                }
                None => {
                    put_u64(&mut out, *id);
                    put_bool_rows(&mut out, images);
                }
            },
            Self::InferOk { id, result, telemetry } => {
                put_u64(&mut out, *id);
                put_result(&mut out, result);
                put_telemetry(&mut out, telemetry);
            }
            Self::Swap { target } => put_layers(&mut out, target),
            Self::SwapOk { report, telemetry } => {
                put_swap_report(&mut out, report);
                put_telemetry(&mut out, telemetry);
            }
            Self::TelemetryOk { telemetry } => put_telemetry(&mut out, telemetry),
            Self::Err { detail } => put_str(&mut out, detail),
            Self::Telemetry | Self::Shutdown | Self::ShutdownOk => {}
        }
        let body_len = (out.len() - 4) as u64;
        if body_len > MAX_FRAME {
            return Err(WireError::Oversized {
                len: body_len,
                max: MAX_FRAME,
            });
        }
        out[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
        Ok(out)
    }

    /// Decode one frame body (version + tag + payload, without the length
    /// prefix).
    pub fn decode_body(body: &[u8]) -> Result<Self, WireError> {
        if body.len() < 2 {
            return Err(WireError::Truncated {
                needed: 2,
                got: body.len(),
            });
        }
        let version = body[0];
        if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&version) {
            return Err(WireError::Version {
                got: version,
                want: PROTOCOL_VERSION,
            });
        }
        let tag = body[1];
        let mut r = Reader::new(&body[2..]);
        let msg = match tag {
            TAG_HELLO => Msg::Hello { magic: r.u32()? },
            TAG_HELLO_OK => Msg::HelloOk {
                caps: r.caps()?,
                telemetry: r.telemetry(version)?,
            },
            TAG_INFER => Msg::Infer {
                id: r.u64()?,
                images: r.bool_rows()?,
            },
            TAG_INFER_PACKED => {
                if version < 2 {
                    // v1 never defined this tag — a v1 frame carrying it
                    // is corrupt, not merely old
                    return Err(WireError::Malformed(
                        "packed infer frame under protocol v1".into(),
                    ));
                }
                let id = r.u64()?;
                let n = r.usize_()?;
                let width = r.usize_()?;
                if width == 0 {
                    return Err(WireError::Malformed(
                        "packed infer frame with zero image width".into(),
                    ));
                }
                let total = n.checked_mul(width).ok_or_else(|| {
                    WireError::Malformed(format!("{n} images x {width} bits overflows"))
                })?;
                // Reader::bits bounds-checks the byte count before any
                // allocation, so a forged n cannot balloon memory
                let bits = r.bits(total)?;
                let images = bits.chunks(width).map(<[bool]>::to_vec).collect();
                Msg::Infer { id, images }
            }
            TAG_INFER_OK => Msg::InferOk {
                id: r.u64()?,
                result: r.result()?,
                telemetry: r.telemetry(version)?,
            },
            TAG_SWAP => Msg::Swap { target: r.layers()? },
            TAG_SWAP_OK => Msg::SwapOk {
                report: r.swap_report()?,
                telemetry: r.telemetry(version)?,
            },
            TAG_TELEMETRY => Msg::Telemetry,
            TAG_TELEMETRY_OK => Msg::TelemetryOk {
                telemetry: r.telemetry(version)?,
            },
            TAG_ERR => Msg::Err { detail: r.str_()? },
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_SHUTDOWN_OK => Msg::ShutdownOk,
            t => return Err(WireError::UnknownTag(t)),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// Read until `buf` is full or the stream ends; returns bytes read.
/// `Interrupted` reads are retried, any other i/o failure is
/// [`WireError::Io`].
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize, WireError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(got)
}

/// Read one frame. `Ok(None)` is a clean end-of-stream (the peer closed
/// between frames); a stream that ends *inside* a frame is
/// [`WireError::Truncated`]. The length prefix is validated against
/// [`MAX_FRAME`] before the body is allocated.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Msg>, WireError> {
    let mut len_buf = [0u8; 4];
    let got = read_full(r, &mut len_buf)?;
    if got == 0 {
        return Ok(None);
    }
    if got < 4 {
        return Err(WireError::Truncated { needed: 4, got });
    }
    let len = u32::from_le_bytes(len_buf) as u64;
    if len > MAX_FRAME {
        return Err(WireError::Oversized {
            len,
            max: MAX_FRAME,
        });
    }
    let mut body = vec![0u8; len as usize];
    let got = read_full(r, &mut body)?;
    if got < body.len() {
        return Err(WireError::Truncated {
            needed: body.len(),
            got,
        });
    }
    Msg::decode_body(&body).map(Some)
}

/// Write one frame.
pub fn write_frame<W: std::io::Write>(w: &mut W, msg: &Msg) -> Result<(), WireError> {
    let frame = msg.to_frame()?;
    w.write_all(&frame).map_err(|e| WireError::Io(e.to_string()))?;
    w.flush().map_err(|e| WireError::Io(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(msg: &Msg) -> Msg {
        let frame = msg.to_frame().unwrap();
        let got = read_frame(&mut Cursor::new(frame)).unwrap().unwrap();
        assert_eq!(&got, msg);
        got
    }

    fn sample_telemetry() -> Telemetry {
        Telemetry {
            batches: 3,
            images: 42,
            steps: 17,
            sim_time: 1.5e-6,
            energy: 2.5e-12,
            swaps: 1,
            wear_pulses: 99,
            utilization: vec![0.25, 0.75],
            ..Telemetry::default()
        }
    }

    #[test]
    fn frames_roundtrip_bit_exactly() {
        roundtrip(&Msg::Hello { magic: MAGIC });
        roundtrip(&Msg::Telemetry);
        roundtrip(&Msg::Shutdown);
        roundtrip(&Msg::ShutdownOk);
        roundtrip(&Msg::Err {
            detail: "θ out of range".into(),
        });
        roundtrip(&Msg::Infer {
            id: 7,
            images: vec![vec![true, false, true], vec![false; 9]],
        });
        roundtrip(&Msg::InferOk {
            id: 7,
            result: InferenceResult {
                bits: vec![vec![true; 5], vec![false, true, false, true, true]],
                classes: vec![4, 1],
                sim_time: 3.25e-7,
                energy: 1.125e-13,
                steps: 10,
            },
            telemetry: sample_telemetry(),
        });
        roundtrip(&Msg::Swap {
            target: vec![BinaryLayer::new(vec![vec![true, false], vec![false, true]], 1)],
        });
        roundtrip(&Msg::SwapOk {
            report: SwapReport {
                set_pulses: 5,
                reset_pulses: 3,
                cells_changed: 8,
                cells_total: 20,
                time: 1e-6,
                energy: 4e-12,
                shards: 1,
            },
            telemetry: sample_telemetry(),
        });
        roundtrip(&Msg::TelemetryOk {
            telemetry: Telemetry::default(),
        });
        roundtrip(&Msg::HelloOk {
            caps: Capabilities {
                kind: BackendKind::Remote,
                n_in: 256,
                n_out: 10,
                max_batch: 64,
                nodes: 4,
                tiles: 3,
                shards: 1,
                reports_energy: true,
                pipelined: false,
            },
            telemetry: sample_telemetry(),
        });
    }

    #[test]
    fn clean_eof_is_none_but_midframe_eof_is_truncated() {
        assert_eq!(read_frame(&mut Cursor::new(Vec::new())).unwrap(), None);
        let frame = Msg::Hello { magic: MAGIC }.to_frame().unwrap();
        for cut in 1..frame.len() {
            let err = read_frame(&mut Cursor::new(frame[..cut].to_vec())).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. }),
                "cut {cut}: {err}"
            );
        }
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        let mut bytes = Vec::new();
        put_u32(&mut bytes, u32::MAX);
        let err = read_frame(&mut Cursor::new(bytes)).unwrap_err();
        assert_eq!(
            err,
            WireError::Oversized {
                len: u32::MAX as u64,
                max: MAX_FRAME
            }
        );
    }

    #[test]
    fn version_mismatch_is_typed() {
        let mut frame = Msg::Telemetry.to_frame().unwrap();
        frame[4] = PROTOCOL_VERSION + 1;
        let err = read_frame(&mut Cursor::new(frame)).unwrap_err();
        assert_eq!(
            err,
            WireError::Version {
                got: PROTOCOL_VERSION + 1,
                want: PROTOCOL_VERSION
            }
        );
    }

    #[test]
    fn unknown_tags_and_trailing_bytes_are_rejected() {
        assert_eq!(
            Msg::decode_body(&[PROTOCOL_VERSION, 200]).unwrap_err(),
            WireError::UnknownTag(200)
        );
        assert!(matches!(
            Msg::decode_body(&[PROTOCOL_VERSION, TAG_SHUTDOWN, 0xFF]).unwrap_err(),
            WireError::Malformed(_)
        ));
    }

    #[test]
    fn forged_counts_cannot_force_allocation() {
        // an Infer frame claiming u64::MAX images in a 16-byte payload
        let mut body = vec![PROTOCOL_VERSION, TAG_INFER];
        put_u64(&mut body, 1);
        put_u64(&mut body, u64::MAX);
        assert!(matches!(
            Msg::decode_body(&body).unwrap_err(),
            WireError::Malformed(_)
        ));
    }

    #[test]
    fn uniform_infer_takes_the_packed_tag_and_shrinks_the_frame() {
        let images: Vec<Vec<bool>> = (0..64)
            .map(|i| (0..25).map(|j| (i + j) % 3 == 0).collect())
            .collect();
        let msg = Msg::Infer { id: 9, images };
        let frame = msg.to_frame().unwrap();
        assert_eq!(frame[5], TAG_INFER_PACKED, "uniform batch packs");
        roundtrip(&msg);
        // header(6) + id(8) + n(8) + width(8) + ceil(64*25/8) bits
        assert_eq!(frame.len(), 6 + 24 + (64 * 25usize).div_ceil(8));
        // the legacy encoding spends 8 length bytes + byte-padded bits
        // per image; the packed frame must be several times smaller
        let legacy = 6 + 8 + 8 + 64 * (8 + 25usize.div_ceil(8));
        assert!(
            frame.len() * 3 < legacy,
            "packed {} vs legacy {legacy}",
            frame.len()
        );
    }

    #[test]
    fn ragged_empty_and_zero_width_batches_keep_the_legacy_tag() {
        let cases = [
            vec![vec![true, false, true], vec![false; 9]], // ragged
            Vec::new(),                                    // empty batch
            vec![Vec::new(), Vec::new()],                  // zero-width
        ];
        for images in cases {
            let msg = Msg::Infer { id: 3, images };
            let frame = msg.to_frame().unwrap();
            assert_eq!(frame[5], TAG_INFER, "{msg:?}");
            roundtrip(&msg);
        }
    }

    #[test]
    fn packed_frames_truncate_cleanly_at_every_cut() {
        let msg = Msg::Infer {
            id: 1,
            images: vec![vec![true; 13]; 5],
        };
        let frame = msg.to_frame().unwrap();
        assert_eq!(frame[5], TAG_INFER_PACKED);
        for cut in 1..frame.len() {
            let err = read_frame(&mut Cursor::new(frame[..cut].to_vec())).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. }),
                "cut {cut}: {err}"
            );
        }
    }

    #[test]
    fn v1_frames_still_decode() {
        assert_eq!(
            Msg::decode_body(&[MIN_PROTOCOL_VERSION, TAG_TELEMETRY]).unwrap(),
            Msg::Telemetry
        );
        // a v1 legacy-encoded infer body decodes identically
        let mut body = vec![MIN_PROTOCOL_VERSION, TAG_INFER];
        put_u64(&mut body, 5);
        put_bool_rows(&mut body, &[vec![true, false, true]]);
        assert_eq!(
            Msg::decode_body(&body).unwrap(),
            Msg::Infer {
                id: 5,
                images: vec![vec![true, false, true]],
            }
        );
    }

    #[test]
    fn packed_tag_under_v1_is_typed_malformed() {
        let frame = Msg::Infer {
            id: 2,
            images: vec![vec![true; 8]; 2],
        }
        .to_frame()
        .unwrap();
        assert_eq!(frame[5], TAG_INFER_PACKED);
        let mut body = frame[4..].to_vec();
        body[0] = MIN_PROTOCOL_VERSION;
        assert!(matches!(
            Msg::decode_body(&body).unwrap_err(),
            WireError::Malformed(_)
        ));
    }

    #[test]
    fn forged_packed_counts_cannot_force_allocation() {
        // n * width overflows usize
        let mut body = vec![PROTOCOL_VERSION, TAG_INFER_PACKED];
        put_u64(&mut body, 1);
        put_u64(&mut body, u64::MAX);
        put_u64(&mut body, u64::MAX);
        assert!(matches!(
            Msg::decode_body(&body).unwrap_err(),
            WireError::Malformed(_)
        ));
        // a forged huge n with width 1 dies on the byte bounds check
        let mut body = vec![PROTOCOL_VERSION, TAG_INFER_PACKED];
        put_u64(&mut body, 1);
        put_u64(&mut body, 1 << 40);
        put_u64(&mut body, 1);
        assert!(matches!(
            Msg::decode_body(&body).unwrap_err(),
            WireError::Truncated { .. }
        ));
        // zero width is typed malformed, not a divide-by-zero
        let mut body = vec![PROTOCOL_VERSION, TAG_INFER_PACKED];
        put_u64(&mut body, 1);
        put_u64(&mut body, 4);
        put_u64(&mut body, 0);
        assert!(matches!(
            Msg::decode_body(&body).unwrap_err(),
            WireError::Malformed(_)
        ));
    }

    #[test]
    fn degenerate_layer_shapes_error_instead_of_panicking() {
        for (n_out, n_in, theta) in [(0u64, 4u64, 1u64), (2, 0, 1), (2, 4, 0)] {
            let mut body = vec![PROTOCOL_VERSION, TAG_SWAP];
            put_u64(&mut body, 1);
            put_u64(&mut body, n_out);
            put_u64(&mut body, n_in);
            put_u64(&mut body, theta);
            assert!(
                matches!(
                    Msg::decode_body(&body).unwrap_err(),
                    WireError::Malformed(_) | WireError::Truncated { .. }
                ),
                "shape {n_out}x{n_in} theta {theta}"
            );
        }
    }
}

//! Monte Carlo variability exhibit (beyond the paper's nominal-corner
//! tables): sweep device corners and resistance variation over the array
//! sizes and show, per size, the noise-margin distribution, the margin
//! failure rate, and the digit-accuracy distribution under variation.
//!
//! The sweep is fully deterministic for a given seed (paired PCG streams,
//! see [`crate::analysis::montecarlo`]), so the `--json` form — which
//! round-trips through [`crate::util::json`] — can be diffed byte-for-byte
//! across runs and machines; CI pins it against a checked-in golden file.

use crate::analysis::{variability_sweep, McConfig, McSizeResult};
use crate::util::json::Json;
use crate::util::si::format_pct;
use crate::util::{Summary, Table};

/// Default noise-margin trials per size of the exhibit.
pub const MC_TRIALS: usize = 48;

/// Default base seed of the exhibit (the corpus seed — the exhibit is an
/// extension of the same workload story).
pub const MC_SEED: u64 = 0x3d_c0ffee;

/// Run the exhibit sweep with the template network.
pub fn montecarlo_rows(seed: u64, trials: usize) -> crate::Result<Vec<McSizeResult>> {
    let cfg = McConfig {
        seed,
        trials,
        ..McConfig::default()
    };
    variability_sweep(&cfg, &super::table2::template_layer())
}

/// Render the per-size distribution table.
pub fn montecarlo_table(rows: &[McSizeResult]) -> Table {
    let mut t = Table::new("Monte Carlo — NM and accuracy under device variation")
        .header(&[
            "Subarray",
            "NM (nom)",
            "NM p50",
            "NM p95..p99",
            "NM min",
            "Fail",
            "Acc (mean)",
            "Acc min",
            "Reset",
        ]);
    for r in rows {
        t.row(&[
            format!("{}×{}", r.n_row, r.n_col),
            format_pct(r.nm_nominal),
            format_pct(r.nm.p50),
            format!("{}..{}", format_pct(r.nm.p95), format_pct(r.nm.p99)),
            format_pct(r.nm.min),
            format_pct(r.failure_rate),
            format_pct(r.accuracy.mean),
            format_pct(r.accuracy.min),
            format_pct(r.reset_rate),
        ]);
    }
    t
}

/// One-line summary: the size axis against the failure axis.
pub fn montecarlo_summary_line(rows: &[McSizeResult]) -> String {
    let fails = rows
        .iter()
        .map(|r| format!("{}r:{}", r.n_row, format_pct(r.failure_rate)))
        .collect::<Vec<_>>()
        .join(" ");
    format!(
        "margin failure rate vs size: {} ({} corners/size, paired across sizes)",
        fails,
        rows.first().map_or(0, |r| r.nm.n),
    )
}

fn summary_json(s: &Summary) -> Json {
    Json::Obj(vec![
        ("n".into(), Json::Num(s.n as f64)),
        ("mean".into(), Json::Num(s.mean)),
        ("std".into(), Json::Num(s.std)),
        ("min".into(), Json::Num(s.min)),
        ("p50".into(), Json::Num(s.p50)),
        ("p95".into(), Json::Num(s.p95)),
        ("p99".into(), Json::Num(s.p99)),
        ("max".into(), Json::Num(s.max)),
    ])
}

/// The `--json` form: the whole sweep as a [`Json`] tree (stable key
/// order; byte-deterministic for a given seed).
pub fn montecarlo_json(seed: u64, trials: usize, rows: &[McSizeResult]) -> Json {
    let sizes = rows
        .iter()
        .map(|r| {
            Json::Obj(vec![
                ("n_row".into(), Json::Num(r.n_row as f64)),
                ("n_col".into(), Json::Num(r.n_col as f64)),
                ("nm_nominal".into(), Json::Num(r.nm_nominal)),
                ("nm".into(), summary_json(&r.nm)),
                ("nm_failures".into(), Json::Num(r.nm_failures as f64)),
                ("failure_rate".into(), Json::Num(r.failure_rate)),
                ("accuracy".into(), summary_json(&r.accuracy)),
                ("reset_rate".into(), Json::Num(r.reset_rate)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("exhibit".into(), Json::Str("montecarlo".into())),
        ("seed".into(), Json::Num(seed as f64)),
        ("trials".into(), Json::Num(trials as f64)),
        ("sizes".into(), Json::Arr(sizes)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_and_summary_render_every_size() {
        let rows = montecarlo_rows(MC_SEED, 8).unwrap();
        assert_eq!(rows.len(), McConfig::default().rows.len());
        let t = montecarlo_table(&rows);
        assert_eq!(t.n_rows(), rows.len());
        let s = t.render();
        assert!(s.contains("Fail"), "{s}");
        let line = montecarlo_summary_line(&rows);
        assert!(line.contains("failure rate") && line.contains("64r:"), "{line}");
    }

    /// Satellite pin: the `--json` exhibit output round-trips through
    /// `util::json` bit-for-bit (parse ∘ render is the identity, and
    /// rendering is a fixed point), its schema is stable, and a second
    /// run with the same seed is byte-identical — the contract behind the
    /// CI golden-file diff of `xpoint montecarlo --json`.
    #[test]
    fn json_snapshot_roundtrips_and_pins_the_schema() {
        let rows = montecarlo_rows(MC_SEED, 8).unwrap();
        let v = montecarlo_json(MC_SEED, 8, &rows);
        let text = v.pretty();
        let parsed = Json::parse(&text).expect("exhibit JSON parses");
        assert_eq!(parsed, v, "parse ∘ pretty is the identity");
        assert_eq!(
            Json::parse(&parsed.render()).unwrap(),
            v,
            "compact form round-trips too"
        );
        // schema snapshot: exact top-level and per-size key order
        match &v {
            Json::Obj(entries) => {
                let keys: Vec<&str> = entries.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(keys, vec!["exhibit", "seed", "trials", "sizes"]);
            }
            other => panic!("expected object, got {other:?}"),
        }
        let size0 = match v.get("sizes") {
            Some(Json::Arr(sizes)) => &sizes[0],
            other => panic!("expected sizes array, got {other:?}"),
        };
        match size0 {
            Json::Obj(entries) => {
                let keys: Vec<&str> = entries.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(
                    keys,
                    vec![
                        "n_row",
                        "n_col",
                        "nm_nominal",
                        "nm",
                        "nm_failures",
                        "failure_rate",
                        "accuracy",
                        "reset_rate"
                    ]
                );
            }
            other => panic!("expected size object, got {other:?}"),
        }
        // deterministic sweep: a second run produces the identical JSON
        let rows2 = montecarlo_rows(MC_SEED, 8).unwrap();
        assert_eq!(
            montecarlo_json(MC_SEED, 8, &rows2).pretty(),
            text,
            "the sweep is bit-deterministic"
        );
    }
}

//! Property tests for the autoscaling policy: for **arbitrary** watermark
//! pairs, bounds, cooldowns and bursty load traces, a fleet that applies
//! every decision stays inside `[min_shards, max_shards]` and consecutive
//! scale events are always separated by at least `cooldown` evaluations.
//! Plus the typed-conflict unit test for `--autoscale` with `--xla`.

use xpoint_imc::cli::Args;
use xpoint_imc::coordinator::{AutoscalePolicy, ScaleDecision};
use xpoint_imc::engine::{AutoscaleSpec, EngineError, EngineSpec, ScaleLoad};
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

/// Draw a random (but always valid) policy spec.
fn arbitrary_spec(rng: &mut Pcg32) -> AutoscaleSpec {
    let min = rng.range(1, 5);
    let max = min + rng.range(0, 5);
    let low = rng.range(0, 40);
    let high = low + rng.range(1, 120);
    AutoscaleSpec {
        min_shards: min,
        max_shards: max,
        high_watermark: high,
        low_watermark: low,
        cooldown: rng.range(0, 6) as u64,
        pulse_budget: 0,
    }
}

/// A bursty backlog trace: alternating quiet and flood segments.
fn arbitrary_backlog(rng: &mut Pcg32, steps: usize) -> Vec<usize> {
    let mut trace = Vec::with_capacity(steps);
    let mut level = 0usize;
    for _ in 0..steps {
        if rng.bernoulli(0.1) {
            // burst edge: jump somewhere new
            level = rng.range(0, 600);
        }
        // jitter around the current level
        let jitter = rng.range(0, 30);
        trace.push(level.saturating_sub(15) + jitter);
        if rng.bernoulli(0.3) && level > 0 {
            level = level.saturating_sub(rng.range(0, 50));
        }
    }
    trace
}

#[test]
fn fleet_stays_in_bounds_and_cooldown_is_respected_for_arbitrary_traces() {
    forall(
        Config::default().cases(300),
        "autoscale bounds + cooldown",
        |rng: &mut Pcg32| {
            let spec = arbitrary_spec(rng);
            spec.validate().map_err(|e| format!("spec invalid: {e}"))?;
            let mut policy = AutoscalePolicy::from_spec(&spec);
            // the model fleet applies every decision instantly — the
            // worst case for bounds (a real engine also back-pressures
            // through ScaleBusy)
            let mut serving = spec.min_shards;
            let mut since_last_event: Option<u64> = None;
            for (step, &backlog) in arbitrary_backlog(rng, 200).iter().enumerate() {
                let load = ScaleLoad {
                    serving,
                    parked: 0,
                    queued_images: backlog / 2,
                    in_flight_images: backlog - backlog / 2,
                };
                let decision = policy.decide(&load);
                match decision {
                    ScaleDecision::Up => serving += 1,
                    ScaleDecision::Down => serving -= 1,
                    ScaleDecision::Hold => {}
                }
                if !(spec.min_shards..=spec.max_shards).contains(&serving) {
                    return Err(format!(
                        "step {step}: serving {serving} left [{}, {}] (spec {spec:?})",
                        spec.min_shards, spec.max_shards
                    ));
                }
                if decision != ScaleDecision::Hold {
                    if let Some(gap) = since_last_event {
                        if gap < spec.cooldown {
                            return Err(format!(
                                "step {step}: only {gap} evaluations since the last \
                                 scale event (cooldown {})",
                                spec.cooldown
                            ));
                        }
                    }
                    since_last_event = Some(0);
                } else if let Some(gap) = since_last_event.as_mut() {
                    *gap += 1;
                }
            }
            Ok(())
        },
    );
}

/// The decision itself is monotone in the obvious way: with the fleet
/// strictly inside its bounds and the cooldown elapsed, backlog above the
/// high watermark always scales up and backlog below the low watermark
/// always scales down.
#[test]
fn watermark_crossings_always_act_when_unconstrained() {
    forall(
        Config::default().cases(300),
        "watermark crossings act",
        |rng: &mut Pcg32| {
            let mut spec = arbitrary_spec(rng);
            spec.max_shards = spec.min_shards + 2;
            spec.cooldown = 0;
            let serving = spec.min_shards + 1; // strictly inside the bounds
            let mut policy = AutoscalePolicy::from_spec(&spec);
            let above = ScaleLoad {
                serving,
                parked: 0,
                queued_images: 0,
                in_flight_images: serving * (spec.high_watermark + 1),
            };
            if policy.decide(&above) != ScaleDecision::Up {
                return Err(format!("backlog above high did not scale up ({spec:?})"));
            }
            if spec.low_watermark > 0 {
                let below = ScaleLoad {
                    serving,
                    parked: 0,
                    queued_images: 0,
                    in_flight_images: serving * (spec.low_watermark - 1),
                };
                let got = policy.decide(&below);
                if got != ScaleDecision::Down {
                    return Err(format!(
                        "backlog below low did not scale down ({spec:?}, {got:?})"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Satellite: `--autoscale` with `--xla` is a typed [`EngineError`], not a
/// string panic.
#[test]
fn autoscale_with_xla_is_a_typed_engine_error() {
    let args = Args::parse(
        "serve --xla --autoscale 1,4"
            .split_whitespace()
            .map(String::from),
    );
    let err = EngineSpec::from_args(&args).unwrap_err();
    assert_eq!(
        err,
        EngineError::Conflict {
            first: "--autoscale",
            second: "--xla",
        }
    );
    assert_eq!(
        err.to_string(),
        "--autoscale and --xla are mutually exclusive — pick one backend"
    );
}

//! The pipelined fabric executor: drives a batch of images through a
//! multi-layer binary network placed across the subarray grid, as a
//! discrete-event simulation.
//!
//! Dataflow per image and layer (paper §IV, Figs. 6/8 generalized):
//!
//! 1. input bits arrive at every tile of the layer (host spine for layer
//!    0, interlink transfers from the previous layer's head nodes after);
//! 2. each tile runs **one computational step** on its node (occupancy
//!    serializes tiles sharing a subarray) producing partial counts for
//!    its row range;
//! 3. partials travel over the interlinks to the row group's *head* node
//!    (the `tile_col == 0` subarray) where they **sum on the linked bit
//!    lines** — count-space accumulation, thresholded once per row group;
//! 4. thresholded bits fan out to the next layer's tiles as soon as their
//!    row group completes — image *i+1* can occupy layer *k−1* while
//!    image *i* is in layer *k*, which is where pipeline overlap comes
//!    from.
//!
//! The executor is **bit-exact** with the functional model: final bits
//! equal `BinaryLayer::forward` chained over the layers, and final counts
//! equal [`tiled_tmvm_counts`](crate::scaling::tiling::tiled_tmvm_counts)
//! of the last layer — while additionally reporting makespan/cycles, per-node
//! utilization, interlink traffic and energy.

use super::event::{secs_to_ticks, ticks_to_secs, EventQueue, Time};
use super::link::{LinkFabric, LinkTraffic};
use super::node::{
    tile_step_packed, tile_step_parasitic, vdd_for_theta, SubarrayNode, TileStep,
};
use super::placement::{place_layers, FabricConfig, Fidelity, Placement};
use super::reprogram::{simulate_reprogram, target_slice, ReprogramRun};
use crate::analysis::{ladder_thevenin, noise_margin, LadderThevenin};
use crate::engine::EngineError;
use crate::nn::packed::{BitMatrix, BitVec};
use crate::nn::BinaryLayer;
use std::ops::Range;

/// Events of the fabric simulation.
#[derive(Clone, Copy, Debug)]
enum Ev {
    /// One input piece arrived at `tiles[tile]`'s node for `image`.
    Piece { image: usize, tile: usize },
    /// `tiles[tile]`'s step finished; its partials start crossing the
    /// interlinks now. (A separate event so link channels are reserved at
    /// the moment the transfer is actually ready — reserving them early,
    /// while the sending node is still busy, would let a later-ready
    /// transfer block an earlier one across an idle link.)
    Send { image: usize, tile: usize },
    /// `tiles[tile]`'s partial counts arrived at its head node.
    Partial { image: usize, tile: usize },
}

/// Result of one pipelined batch.
#[derive(Clone, Debug)]
pub struct FabricRun {
    /// Final-layer thresholded bits, `[image][neuron]`.
    pub outputs: Vec<Vec<bool>>,
    /// Final-layer pre-threshold counts (as accumulated through the
    /// linked bit lines), `[image][neuron]`.
    pub final_counts: Vec<Vec<u32>>,
    /// Simulated end-to-end time of the batch \[s\].
    pub makespan: f64,
    /// Makespan in computational-step quanta (`⌈makespan / t_SET⌉`).
    pub cycles: u64,
    /// TMVM steps executed across all subarrays.
    pub steps: u64,
    /// Energy of the computational steps \[J\].
    pub compute_energy: f64,
    /// Switch losses of interlink + host-spine transfers \[J\].
    pub link_energy: f64,
    /// Total batch energy \[J\].
    pub energy: f64,
    /// Per-subarray busy fraction of the makespan.
    pub utilization: Vec<f64>,
    /// Interlink traffic counters.
    pub traffic: LinkTraffic,
    /// Per-image completion time \[s\].
    pub per_image_done: Vec<f64>,
    /// Worst (smallest) per-tile corner-case noise margin across the
    /// placed tiles, each evaluated at its own grid position and engaged
    /// span ([`FabricConfig::tile_design`]). `+∞` at ideal fidelity —
    /// no electrical window is modeled there.
    pub margin_min: f64,
    /// Rows whose attenuated parasitic current reached `I_RESET` during
    /// this batch (always 0 at ideal fidelity).
    pub reset_violations: u64,
}

impl FabricRun {
    /// Mean subarray utilization.
    pub fn mean_utilization(&self) -> f64 {
        if self.utilization.is_empty() {
            0.0
        } else {
            self.utilization.iter().sum::<f64>() / self.utilization.len() as f64
        }
    }

    /// Simulated throughput \[images/s\].
    pub fn throughput(&self) -> f64 {
        if self.makespan > 0.0 {
            self.outputs.len() as f64 / self.makespan
        } else {
            0.0
        }
    }
}

/// A multi-layer binary network placed on a fabric, ready to execute
/// batches. Construction validates shapes and precomputes placement and
/// per-layer operating voltages; `run_batch` is pure simulation (no
/// wall-clock, bit-reproducible).
#[derive(Clone, Debug)]
pub struct FabricExecutor {
    cfg: FabricConfig,
    layers: Vec<BinaryLayer>,
    placement: Placement,
    /// Per-layer operating voltage realizing the layer's θ.
    v_dd: Vec<f64>,
    /// One computational step in ticks.
    t_step: Time,
    /// Row range of each global row group.
    group_rows: Vec<Range<usize>>,
    /// Column tiles feeding each global row group.
    group_width: Vec<usize>,
    /// Input pieces each tile waits for (per image).
    init_pieces: Vec<usize>,
    /// Each placed tile's weights packed once at placement (index-aligned
    /// with `placement.tiles`) and reused by every event, instead of
    /// re-walking the tile's `Vec<Vec<bool>>` slice per step. Rebuilt on
    /// `reprogram`, the only thing that mutates placed weights.
    packed_tiles: Vec<BitMatrix>,
    /// Parasitic fidelity only: each tile's per-row Thevenin ladder
    /// (`tile_thevenin[tile][r]` = the equivalent seen by local row `r+1`
    /// of the tile's subarray design), index-aligned with
    /// `placement.tiles`. Geometry-only — survives `reprogram` untouched.
    /// Empty at ideal fidelity.
    tile_thevenin: Vec<Vec<LadderThevenin>>,
    /// Worst per-tile static noise margin (see [`FabricRun::margin_min`]).
    margin_min: f64,
}

impl FabricExecutor {
    pub fn new(layers: Vec<BinaryLayer>, cfg: FabricConfig) -> crate::Result<Self> {
        let placement = place_layers(&layers, &cfg)?;
        let v_dd = layers
            .iter()
            .map(|l| vdd_for_theta(l.theta, &cfg.device))
            .collect();
        let t_step = secs_to_ticks(cfg.device.t_set).max(1);

        let mut group_rows = Vec::with_capacity(placement.n_groups);
        let mut group_width = Vec::with_capacity(placement.n_groups);
        for tiling in &placement.tilings {
            for tr in 0..tiling.grid_rows() {
                group_rows.push(tiling.row_range(tr));
                group_width.push(tiling.grid_cols());
            }
        }

        let init_pieces = placement
            .tiles
            .iter()
            .map(|tile| {
                if tile.layer == 0 {
                    1
                } else {
                    let pt = &placement.tilings[tile.layer - 1];
                    (0..pt.grid_rows())
                        .filter(|&tr| {
                            let rr = pt.row_range(tr);
                            rr.start < tile.col_range.end && tile.col_range.start < rr.end
                        })
                        .count()
                }
            })
            .collect();

        let packed_tiles = placement
            .tiles
            .iter()
            .map(|tile| BitMatrix::from_rows(&tile.weights))
            .collect();

        // Parasitic fidelity: each tile's subarray gets its own Thevenin
        // ladder (position-dependent driver resistance, engaged span) and
        // a static corner-case margin. Computed once — the ladders depend
        // only on geometry, never on the programmed weights, so they
        // survive `reprogram` untouched.
        let (tile_thevenin, margin_min) = match cfg.fidelity {
            Fidelity::Ideal => (Vec::new(), f64::INFINITY),
            Fidelity::Parasitic => {
                let mut ladders = Vec::with_capacity(placement.tiles.len());
                let mut worst = f64::INFINITY;
                for tile in &placement.tiles {
                    let design = cfg.tile_design(tile);
                    ladders.push(
                        (1..=tile.weights.len())
                            .map(|row| ladder_thevenin(&design, row))
                            .collect::<Vec<_>>(),
                    );
                    worst = worst.min(noise_margin(&design).noise_margin());
                }
                (ladders, worst)
            }
        };

        Ok(Self {
            cfg,
            layers,
            placement,
            v_dd,
            t_step,
            group_rows,
            group_width,
            init_pieces,
            packed_tiles,
            tile_thevenin,
            margin_min,
        })
    }

    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    pub fn layers(&self) -> &[BinaryLayer] {
        &self.layers
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Worst per-tile static corner-case noise margin of the placement
    /// (`+∞` at ideal fidelity — see [`FabricRun::margin_min`]).
    pub fn margin_min(&self) -> f64 {
        self.margin_min
    }

    /// Check that `target` can be programmed into the current placement:
    /// same layer count and per-layer dimensions (θ may change freely —
    /// it is realized by the operating voltage, not the stored bits).
    pub fn validate_swap(&self, target: &[BinaryLayer]) -> Result<(), EngineError> {
        if target.len() != self.layers.len() {
            return Err(EngineError::SwapShape {
                detail: format!(
                    "target has {} layer(s), the placed network has {}",
                    target.len(),
                    self.layers.len()
                ),
            });
        }
        for (k, (cur, tgt)) in self.layers.iter().zip(target).enumerate() {
            if cur.n_out() != tgt.n_out() || cur.n_in() != tgt.n_in() {
                return Err(EngineError::SwapShape {
                    detail: format!(
                        "layer {k} is {}×{} but the target is {}×{}",
                        cur.n_out(),
                        cur.n_in(),
                        tgt.n_out(),
                        tgt.n_in()
                    ),
                });
            }
        }
        Ok(())
    }

    /// Reprogram the fabric to `target` in place: simulate the rewrite
    /// (spine weight traffic + per-node write-driver occupancy — see
    /// [`simulate_reprogram`]), then swap the resident weights and
    /// per-layer operating voltages. Validation and simulation complete
    /// before any mutation, so a failed swap leaves the old network fully
    /// intact and a successful one is atomic — the next `run_batch` is
    /// wholly-new, never a torn mix.
    pub fn reprogram(&mut self, target: Vec<BinaryLayer>) -> crate::Result<ReprogramRun> {
        self.validate_swap(&target)?;
        let run = simulate_reprogram(&self.placement, &self.cfg, &target)?;
        for tile in &mut self.placement.tiles {
            tile.weights = target_slice(tile, &target);
        }
        self.packed_tiles = self
            .placement
            .tiles
            .iter()
            .map(|tile| BitMatrix::from_rows(&tile.weights))
            .collect();
        self.v_dd = target
            .iter()
            .map(|l| vdd_for_theta(l.theta, &self.cfg.device))
            .collect();
        self.layers = target;
        Ok(run)
    }

    /// Execute a batch of images through the pipelined fabric. Each run is
    /// an independent simulation starting at t = 0 with idle resources.
    pub fn run_batch(&self, images: &[Vec<bool>]) -> crate::Result<FabricRun> {
        let m = images.len();
        let l_count = self.layers.len();
        let n_in0 = self.layers[0].n_in();
        for (i, img) in images.iter().enumerate() {
            anyhow::ensure!(
                img.len() == n_in0,
                "image {i}: {} pixels, expected {n_in0}",
                img.len()
            );
        }
        let p = self.cfg.device;
        let placement = &self.placement;
        let t_count = placement.n_tiles();
        let n_out_last = self.layers[l_count - 1].n_out();

        let mut nodes: Vec<SubarrayNode> = (0..self.cfg.n_nodes())
            .map(|n| {
                let (r, c) = self.cfg.node_coords(n);
                SubarrayNode::new(n, r, c)
            })
            .collect();
        let mut links = LinkFabric::new(&self.cfg);
        let mut queue: EventQueue<Ev> = EventQueue::new();

        // per-image state
        let mut outputs: Vec<Vec<Vec<bool>>> = (0..m)
            .map(|_| self.layers.iter().map(|l| vec![false; l.n_out()]).collect())
            .collect();
        let mut pieces_pending: Vec<Vec<usize>> = vec![self.init_pieces.clone(); m];
        let mut stash: Vec<Vec<Option<TileStep>>> = vec![vec![None; t_count]; m];
        let mut acc_counts: Vec<Vec<Vec<u32>>> = (0..m)
            .map(|_| self.group_rows.iter().map(|r| vec![0u32; r.len()]).collect())
            .collect();
        let mut acc_pending: Vec<Vec<usize>> = vec![self.group_width.clone(); m];
        let layer_groups: Vec<usize> = placement.tilings.iter().map(|t| t.grid_rows()).collect();
        let mut groups_left: Vec<Vec<usize>> = vec![layer_groups; m];
        let mut done_at: Vec<Time> = vec![0; m];
        let mut reset_violations = 0u64;

        // host injection: image i enters the fabric at i · t_inject
        let t_inject = secs_to_ticks(self.cfg.t_inject);
        for (i, image) in images.iter().enumerate() {
            let ready = i as Time * t_inject;
            for &ti in &placement.by_layer[0] {
                let tile = &placement.tiles[ti];
                let lines = tile.col_range.len() as u64;
                let set = image[tile.col_range.clone()].iter().filter(|&&b| b).count();
                let arrival =
                    links.transfer_input(ready, tile.node, lines, set as f64 * p.i_set);
                queue.schedule(arrival, Ev::Piece { image: i, tile: ti });
            }
        }

        while let Some((now, ev)) = queue.pop() {
            match ev {
                Ev::Piece { image, tile } => {
                    pieces_pending[image][tile] -= 1;
                    if pieces_pending[image][tile] > 0 {
                        continue;
                    }
                    let t = &placement.tiles[tile];
                    // all input pieces arrived: run the tile's TMVM step.
                    // Ideal fidelity takes the packed popcount fast path
                    // against the tile packed at placement time; parasitic
                    // fidelity runs the per-cell electrical walk through
                    // the tile's own Thevenin ladder (bit-exact with the
                    // scalar oracle, so it must stay off the packed path).
                    let step = {
                        let x_full: &[bool] = if t.layer == 0 {
                            &images[image]
                        } else {
                            &outputs[image][t.layer - 1]
                        };
                        match self.cfg.fidelity {
                            Fidelity::Ideal => tile_step_packed(
                                &self.packed_tiles[tile],
                                &BitVec::from_bools(&x_full[t.col_range.clone()]),
                                self.v_dd[t.layer],
                                &p,
                            ),
                            Fidelity::Parasitic => {
                                let ps = tile_step_parasitic(
                                    &t.weights,
                                    &x_full[t.col_range.clone()],
                                    self.v_dd[t.layer],
                                    &p,
                                    &self.tile_thevenin[tile],
                                );
                                reset_violations += ps.reset_violations as u64;
                                ps.into_tile_step()
                            }
                        }
                    };
                    let node = &mut nodes[t.node];
                    let (_start, end) = node.reserve_step(now, self.t_step);
                    node.ledger
                        .book_step(self.v_dd[t.layer], step.current_sum, p.t_set);
                    stash[image][tile] = Some(step);
                    queue.schedule(end, Ev::Send { image, tile });
                }
                Ev::Send { image, tile } => {
                    // the step just finished: ship the partial counts to
                    // the row group's head node, reserving interlinks now
                    let t = &placement.tiles[tile];
                    let (lines, i_tot) = {
                        let step = stash[image][tile].as_ref().expect("step was stashed");
                        (step.counts.len() as u64, step.current_sum)
                    };
                    let head = placement.heads[t.layer][t.tile_row];
                    let arrival = links.transfer(now, t.node, head, lines, i_tot);
                    queue.schedule(arrival, Ev::Partial { image, tile });
                }
                Ev::Partial { image, tile } => {
                    let t = &placement.tiles[tile];
                    let step = stash[image][tile].take().expect("partial was stashed");
                    let g = placement.group_id(t.layer, t.tile_row);
                    // current summing on the linked bit lines: count-space
                    // accumulation at the head node
                    for (k, &c) in step.counts.iter().enumerate() {
                        acc_counts[image][g][k] += c;
                    }
                    acc_pending[image][g] -= 1;
                    if acc_pending[image][g] > 0 {
                        continue;
                    }
                    // all column tiles merged: threshold this row group
                    let layer = t.layer;
                    let theta = self.layers[layer].theta;
                    let row_range = self.group_rows[g].clone();
                    for (k, r) in row_range.clone().enumerate() {
                        outputs[image][layer][r] = acc_counts[image][g][k] as usize >= theta;
                    }
                    groups_left[image][layer] -= 1;
                    if layer + 1 == l_count {
                        if groups_left[image][layer] == 0 {
                            done_at[image] = now;
                        }
                    } else {
                        // fan the fresh bits out to next-layer tiles that
                        // consume any of these rows as input columns
                        let head = placement.heads[layer][t.tile_row];
                        for &t2 in &placement.by_layer[layer + 1] {
                            let tile2 = &placement.tiles[t2];
                            let lo = row_range.start.max(tile2.col_range.start);
                            let hi = row_range.end.min(tile2.col_range.end);
                            if lo >= hi {
                                continue;
                            }
                            let set = outputs[image][layer][lo..hi]
                                .iter()
                                .filter(|&&b| b)
                                .count();
                            let arrival = links.transfer(
                                now,
                                head,
                                tile2.node,
                                (hi - lo) as u64,
                                set as f64 * p.i_set,
                            );
                            queue.schedule(arrival, Ev::Piece { image, tile: t2 });
                        }
                    }
                }
            }
        }

        // simulator invariant: every image drained through every layer
        assert!(
            groups_left.iter().all(|per| per.iter().all(|&g| g == 0)),
            "fabric deadlock: undrained row groups"
        );

        let makespan_ticks = queue.now();
        let makespan = ticks_to_secs(makespan_ticks);
        let final_counts: Vec<Vec<u32>> = (0..m)
            .map(|i| {
                let mut v = vec![0u32; n_out_last];
                let lt = l_count - 1;
                let tiling = &placement.tilings[lt];
                for tr in 0..tiling.grid_rows() {
                    let g = placement.group_id(lt, tr);
                    for (k, r) in tiling.row_range(tr).enumerate() {
                        v[r] = acc_counts[i][g][k];
                    }
                }
                v
            })
            .collect();
        let final_bits: Vec<Vec<bool>> =
            outputs.into_iter().map(|mut per| per.pop().expect("≥1 layer")).collect();

        let compute_energy: f64 = nodes.iter().map(|n| n.ledger.energy).sum();
        let traffic = links.totals();
        let link_energy = traffic.energy + traffic.input_energy;
        let steps: u64 = nodes.iter().map(|n| n.ledger.steps).sum();
        let utilization: Vec<f64> = nodes.iter().map(|n| n.utilization(makespan)).collect();
        let cycles = makespan_ticks.div_ceil(self.t_step);

        Ok(FabricRun {
            outputs: final_bits,
            final_counts,
            makespan,
            cycles,
            steps,
            compute_energy,
            link_energy,
            energy: compute_energy + link_energy,
            utilization,
            traffic,
            per_image_done: done_at.iter().map(|&t| ticks_to_secs(t)).collect(),
            margin_min: self.margin_min,
            reset_violations,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            theta,
        )
    }

    fn chain_forward(layers: &[BinaryLayer], x: &[bool]) -> Vec<bool> {
        let mut v = x.to_vec();
        for l in layers {
            v = l.forward(&v);
        }
        v
    }

    #[test]
    fn single_tile_layer_matches_functional_forward() {
        let mut rng = Pcg32::seeded(91);
        let layer = random_layer(&mut rng, 6, 12, 3);
        let exec =
            FabricExecutor::new(vec![layer.clone()], FabricConfig::new(1, 1, 16, 16)).unwrap();
        let images: Vec<Vec<bool>> = (0..5)
            .map(|_| (0..12).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let run = exec.run_batch(&images).unwrap();
        for (i, img) in images.iter().enumerate() {
            assert_eq!(run.outputs[i], layer.forward(img), "image {i}");
            assert_eq!(run.final_counts[i], layer.counts(img), "image {i} counts");
        }
        assert_eq!(run.steps, 5, "one step per image on a single tile");
        // single tile: no grid traffic, host spine only
        assert_eq!(run.traffic.transfers, 0);
        assert_eq!(run.traffic.input_transfers, 5);
        assert!(run.compute_energy > 0.0 && run.makespan > 0.0);
        assert_eq!(run.utilization.len(), 1);
    }

    #[test]
    fn split_columns_accumulate_through_links() {
        let mut rng = Pcg32::seeded(92);
        let layer = random_layer(&mut rng, 4, 30, 5);
        // 30 input cols over 8-wide tiles → 4 column tiles, 1 row group
        let exec =
            FabricExecutor::new(vec![layer.clone()], FabricConfig::new(2, 2, 8, 8)).unwrap();
        let images: Vec<Vec<bool>> = (0..6)
            .map(|_| (0..30).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let run = exec.run_batch(&images).unwrap();
        for (i, img) in images.iter().enumerate() {
            assert_eq!(run.outputs[i], layer.forward(img), "image {i}");
            assert_eq!(run.final_counts[i], layer.counts(img), "image {i}");
        }
        assert_eq!(run.steps, 6 * 4);
        assert!(run.traffic.transfers > 0, "partials crossed the fabric");
        assert!(run.traffic.lines > 0 && run.link_energy > 0.0);
    }

    #[test]
    fn multilayer_matches_chained_forward() {
        let mut rng = Pcg32::seeded(93);
        let layers = vec![
            random_layer(&mut rng, 10, 20, 4),
            random_layer(&mut rng, 7, 10, 2),
            random_layer(&mut rng, 3, 7, 1),
        ];
        let exec = FabricExecutor::new(layers.clone(), FabricConfig::new(2, 3, 8, 8)).unwrap();
        let images: Vec<Vec<bool>> = (0..9)
            .map(|_| (0..20).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let run = exec.run_batch(&images).unwrap();
        for (i, img) in images.iter().enumerate() {
            assert_eq!(run.outputs[i], chain_forward(&layers, img), "image {i}");
        }
        assert!(run.cycles > 0);
        assert!(run.per_image_done.iter().all(|&t| t > 0.0 && t <= run.makespan));
    }

    #[test]
    fn pipelining_overlaps_images_across_layers() {
        let mut rng = Pcg32::seeded(94);
        let layers = vec![
            random_layer(&mut rng, 12, 16, 3),
            random_layer(&mut rng, 12, 12, 3),
            random_layer(&mut rng, 8, 12, 2),
        ];
        let exec = FabricExecutor::new(layers, FabricConfig::new(2, 2, 16, 16)).unwrap();
        let one: Vec<Vec<bool>> = (0..1)
            .map(|_| (0..16).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let latency = exec.run_batch(&one).unwrap().makespan;
        let m = 8;
        let many: Vec<Vec<bool>> = (0..m)
            .map(|_| (0..16).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let run = exec.run_batch(&many).unwrap();
        assert!(
            run.makespan < 0.75 * m as f64 * latency,
            "no overlap: {} images took {} vs latency {}",
            m,
            run.makespan,
            latency
        );
    }

    #[test]
    fn runs_are_deterministic() {
        let mut rng = Pcg32::seeded(95);
        let layers = vec![
            random_layer(&mut rng, 9, 14, 2),
            random_layer(&mut rng, 5, 9, 2),
        ];
        let exec = FabricExecutor::new(layers, FabricConfig::new(2, 2, 8, 8)).unwrap();
        let images: Vec<Vec<bool>> = (0..7)
            .map(|_| (0..14).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let a = exec.run_batch(&images).unwrap();
        let b = exec.run_batch(&images).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.energy.to_bits(), b.energy.to_bits());
        assert_eq!(a.outputs, b.outputs);
        assert_eq!(a.traffic.transfers, b.traffic.transfers);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut rng = Pcg32::seeded(96);
        let layer = random_layer(&mut rng, 3, 6, 1);
        let exec = FabricExecutor::new(vec![layer], FabricConfig::new(1, 1, 8, 8)).unwrap();
        let run = exec.run_batch(&[]).unwrap();
        assert_eq!(run.outputs.len(), 0);
        assert_eq!(run.makespan, 0.0);
        assert_eq!(run.steps, 0);
        assert_eq!(run.cycles, 0);
    }

    #[test]
    fn wrong_image_width_rejected() {
        let mut rng = Pcg32::seeded(97);
        let layer = random_layer(&mut rng, 3, 6, 1);
        let exec = FabricExecutor::new(vec![layer], FabricConfig::new(1, 1, 8, 8)).unwrap();
        let err = exec.run_batch(&[vec![true; 5]]).unwrap_err();
        assert!(err.to_string().contains("expected 6"), "{err}");
    }
}

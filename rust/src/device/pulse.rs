//! Programming waveforms (paper Fig. 2(a)): SET, RESET, READ pulses.

use super::params::DeviceParams;

/// The three memory operations available in 3D XPoint (§II).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PulseKind {
    /// Fast, high-amplitude — write logic 0.
    Reset,
    /// Slow, low-amplitude — write logic 1.
    Set,
    /// Very small amplitude — non-destructive read.
    Read,
}

/// A rectangular current pulse.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pulse {
    pub kind: PulseKind,
    /// Amplitude \[A\].
    pub amplitude: f64,
    /// Duration \[s\].
    pub duration: f64,
}

impl Pulse {
    pub fn set(p: &DeviceParams) -> Self {
        Self {
            kind: PulseKind::Set,
            amplitude: p.i_set,
            duration: p.t_set,
        }
    }

    pub fn reset(p: &DeviceParams) -> Self {
        Self {
            kind: PulseKind::Reset,
            amplitude: p.i_reset,
            duration: p.t_reset,
        }
    }

    pub fn read(p: &DeviceParams) -> Self {
        Self {
            kind: PulseKind::Read,
            amplitude: p.i_read,
            duration: p.t_read,
        }
    }

    /// Charge delivered \[C\].
    pub fn charge(&self) -> f64 {
        self.amplitude * self.duration
    }

    /// Energy dissipated across an element of conductance `g` \[J\]
    /// (`E = I²/G · t`).
    pub fn energy(&self, g: f64) -> f64 {
        self.amplitude * self.amplitude / g * self.duration
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_pulses_match_params() {
        let p = DeviceParams::default();
        let s = Pulse::set(&p);
        assert_eq!(s.amplitude, 50e-6);
        assert_eq!(s.duration, 80e-9);
        let r = Pulse::reset(&p);
        assert_eq!(r.amplitude, 100e-6);
        assert_eq!(r.duration, 15e-9);
        assert!(Pulse::read(&p).amplitude < s.amplitude / 10.0);
    }

    #[test]
    fn reset_is_fast_and_high_set_is_slow_and_low() {
        let p = DeviceParams::default();
        let s = Pulse::set(&p);
        let r = Pulse::reset(&p);
        assert!(r.amplitude > s.amplitude);
        assert!(r.duration < s.duration);
    }

    #[test]
    fn energy_scales_with_duration_and_square_current() {
        let p = DeviceParams::default();
        let s = Pulse::set(&p);
        let e1 = s.energy(p.g_c);
        // doubling current at equal duration quadruples energy
        let double = Pulse {
            amplitude: 2.0 * s.amplitude,
            ..s
        };
        assert!((double.energy(p.g_c) / e1 - 4.0).abs() < 1e-12);
        // SET through a crystalline cell ~ pJ scale (sanity for Table II)
        assert!(e1 > 0.1e-12 && e1 < 100e-12, "E_set = {e1}");
    }
}

//! Integration: the L3 coordinator end-to-end on the digit workload with
//! simulator backends.

use std::time::Duration;
use xpoint_imc::array::TmvmMode;
use xpoint_imc::coordinator::{BackendFactory, Coordinator, CoordinatorConfig};
use xpoint_imc::engine::{ArraySpec, BackendKind, EngineSpec, NetworkSource};
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::report::table2::template_layer;

fn sim_factories(n: usize, n_row: usize, mode: TmvmMode) -> Vec<BackendFactory> {
    let kind = match mode {
        TmvmMode::Ideal => BackendKind::Ideal,
        TmvmMode::Parasitic => BackendKind::Parasitic,
    };
    EngineSpec::new(kind)
        .with_workers(n)
        .with_network(NetworkSource::Template)
        .with_array(ArraySpec {
            rows: n_row,
            cols: 128,
            span: Some(121),
            ..ArraySpec::default()
        })
        .build_factories()
        .expect("valid engine spec")
}

#[test]
fn serves_digit_corpus_with_accuracy_and_energy() {
    let mut coord = Coordinator::spawn(
        sim_factories(2, 64, TmvmMode::Ideal),
        CoordinatorConfig {
            batch_capacity: 64,
            linger: Duration::from_micros(100),
            autoscale: None,
        },
    );
    let layer = template_layer();
    let mut gen = DigitGen::new(TEST_SEED);
    let n = 512;
    let mut expected = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let s = gen.next_sample();
        expected.push((layer.forward(&s.pixels), layer.argmax(&s.pixels)));
        rxs.push(coord.submit(s.pixels, Some(s.label)).expect("submit"));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
        assert_eq!(pred.bits, expected[i].0, "request {i} bits");
        assert_eq!(pred.class, expected[i].1, "request {i} class");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.images, n as u64);
    let acc = snap.accuracy.expect("labelled requests");
    assert!(acc > 0.5, "accuracy {acc}");
    // Table II scale: tens of pJ per image
    assert!(
        snap.energy_per_image > 1e-12 && snap.energy_per_image < 100e-12,
        "energy/image {}",
        snap.energy_per_image
    );
    // simulated array time: each 64-image batch runs 10 steps of 80 ns
    let batches = snap.batches as f64;
    assert!(
        snap.sim_time >= batches * 10.0 * 80e-9 * 0.9,
        "sim time {} for {batches} batches",
        snap.sim_time
    );
}

#[test]
fn throughput_scales_with_workers() {
    // wall-clock throughput with 4 workers must beat 1 worker on the same
    // load (coarse check: ≥1.3×). Parasitic mode makes the per-batch
    // compute heavy enough that workers, not the leader, dominate.
    let run = |workers: usize| -> f64 {
        let mut coord = Coordinator::spawn(
            sim_factories(workers, 256, TmvmMode::Parasitic),
            CoordinatorConfig {
                batch_capacity: 64,
                linger: Duration::from_micros(50),
                autoscale: None,
            },
        );
        let mut gen = DigitGen::new(1);
        let n = 2048;
        let started = std::time::Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|_| coord.submit(gen.next_sample().pixels, None).expect("submit"))
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(60)).expect("reply");
        }
        let dt = started.elapsed().as_secs_f64();
        coord.shutdown();
        n as f64 / dt
    };
    let t1 = run(1);
    let t4 = run(4);
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    if cores >= 2 {
        assert!(
            t4 > 1.3 * t1,
            "4 workers {t4:.0} img/s vs 1 worker {t1:.0} img/s on {cores} cores"
        );
    } else {
        // single-core host: scaling is impossible; require that the
        // multi-worker topology at least doesn't collapse
        assert!(
            t4 > 0.5 * t1,
            "4 workers {t4:.0} img/s vs 1 worker {t1:.0} img/s on 1 core"
        );
        eprintln!("NOTE: 1 CPU available — parallel-scaling assertion skipped");
    }
}

#[test]
fn partial_batches_flush_on_linger() {
    let mut coord = Coordinator::spawn(
        sim_factories(1, 64, TmvmMode::Ideal),
        CoordinatorConfig {
            batch_capacity: 64,
            linger: Duration::from_millis(1),
            autoscale: None,
        },
    );
    let mut gen = DigitGen::new(2);
    // submit fewer than a batch; linger must flush them
    let rxs: Vec<_> = (0..5)
        .map(|_| coord.submit(gen.next_sample().pixels, None).expect("submit"))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(10)).expect("linger flush");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.images, 5);
}

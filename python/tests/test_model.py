"""L2 model tests: training quality, threshold selection, MLP pipeline."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.dataset import DigitGen
from compile.kernels import ref


@pytest.fixture(scope="module")
def corpus():
    train = DigitGen(seed=0x7121).dataset(1200)
    test = DigitGen(seed=0x9999).dataset(400)
    return train, test


@pytest.fixture(scope="module")
def trained(corpus):
    (train_x, train_y), _ = corpus
    w = model.train_single_layer(train_x, train_y)
    theta = model.pick_theta(train_x, train_y, w)
    return w, theta


def test_single_layer_accuracy(corpus, trained):
    _, (test_x, test_y) = corpus
    w, _ = trained
    acc = model.accuracy_argmax(test_x, test_y, w)
    # paper quotes 91% for scaled MNIST; the synthetic corpus is easier
    assert acc >= 0.90, f"argmax accuracy {acc}"


def test_weights_are_binary(trained):
    w, _ = trained
    assert set(np.unique(w)) <= {0.0, 1.0}
    assert w.shape == (121, 10)


def test_theta_yields_onehot_behaviour(corpus, trained):
    _, (test_x, test_y) = corpus
    w, theta = trained
    counts = test_x @ w
    fired = counts >= theta
    correct = fired[np.arange(len(test_y)), test_y]
    others = fired.sum(axis=1) - correct
    onehot = np.mean(correct & (others == 0))
    # the shared firing threshold (one V_DD per step) caps clean one-hot
    # behaviour well below argmax accuracy — a real hardware constraint
    assert onehot >= 0.25, f"one-hot validity {onehot}"


def test_inference_graph_matches_counts(corpus, trained):
    _, (test_x, test_y) = corpus
    w, theta = trained
    x = test_x[:64]
    alpha = np.ones((64, 1), np.float32)
    r_th = np.zeros((64, 1), np.float32)
    v_dd = np.array([[ref.vdd_for_threshold(theta)]], np.float32)
    bits, _ = model.single_layer_infer(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(alpha), jnp.asarray(r_th), jnp.asarray(v_dd)
    )
    bits = np.asarray(bits)
    counts = x @ w
    expect = (counts >= theta).astype(np.float32)
    # amorphous leakage can only promote a count sitting exactly at the
    # boundary; with integer counts and leakage << 1 count, exact agreement
    np.testing.assert_array_equal(bits, expect)


def test_mlp_trains_and_beats_chance(corpus):
    (train_x, train_y), (test_x, test_y) = corpus
    w1, w2 = model.train_mlp(train_x, train_y, n_hidden=64, theta1=14, epochs=120)
    acc = model.mlp_accuracy(test_x, test_y, w1, 14, w2)
    assert acc >= 0.55, f"mlp accuracy {acc}"
    assert set(np.unique(w1)) <= {0.0, 1.0}
    assert set(np.unique(w2)) <= {0.0, 1.0}


def test_mlp_infer_graph_runs(corpus):
    (train_x, train_y), _ = corpus
    w1, w2 = model.train_mlp(train_x[:400], train_y[:400], n_hidden=32, theta1=8, epochs=5)
    x = train_x[:64]
    v1 = np.array([[ref.vdd_for_threshold(8)]], np.float32)
    v2 = np.array([[ref.vdd_for_threshold(2)]], np.float32)
    bits, _ = model.mlp_infer(
        jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2), jnp.asarray(v1), jnp.asarray(v2)
    )
    assert np.asarray(bits).shape == (64, 10)
    assert set(np.unique(np.asarray(bits))) <= {0.0, 1.0}

//! The in-memory TMVM engine (paper §III-A).
//!
//! Semantics: binary matrix `G` lives in the top PCM level (`G[row][col]`),
//! the binary input vector `V` is applied on the word lines (one entry per
//! column; logic 0 = floated line), and each row's thresholded dot product
//! lands in the bottom-level output column:
//!
//! ```text
//! I_T(row) = G_C · V_DD · Σ_i(V_i·G[row][i]) / (Σ_{V_i=1} G[row][i] + G_C)   (Eq. 3, at the
//! O(row)   = I_T(row) ≥ I_SET                                 crystalline endpoint)
//! ```
//!
//! An execution is *electrically erroneous* if any output current reaches
//! `I_RESET` (accidental RESET, §III-A) — the engine reports violations
//! instead of silently clamping. In [`TmvmMode::Parasitic`] the per-row
//! Thevenin attenuation of the word-line ladder divides the delivered
//! voltage and adds the wire resistance into the current path.

use super::subarray::Subarray;
use crate::analysis::thevenin::ladder_thevenin;
use crate::device::DeviceParams;
use crate::nn::packed::{and_count, BitVec};

/// Eq. 3 at the crystalline endpoint, in **count space**: with `count`
/// crystalline products among `active` driven inputs the conductance sum
/// is exactly `count·G_C + (active−count)·G_A` (a binary-programmed level
/// has no intermediate states), so the row current needs a popcount, not
/// a per-cell walk. The fabric node's `row_current` delegates here, which
/// keeps the two layers bit-identical in f64.
#[inline]
pub fn ideal_row_current(count: u32, active: u32, v_dd: f64, p: &DeviceParams) -> f64 {
    if active == 0 {
        return 0.0;
    }
    let g_sum = f64::from(count) * p.g_c + f64::from(active - count) * p.g_a;
    p.g_c * v_dd * g_sum / (g_sum + p.g_c)
}

/// Electrical fidelity of a TMVM execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TmvmMode {
    /// Eq. 3 exactly — no wire parasitics.
    Ideal,
    /// Per-row Thevenin attenuation + series wire resistance from the
    /// Appendix-A ladder model.
    Parasitic,
}

/// Per-row electrical outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TmvmOutcome {
    /// Output SET to logic 1.
    Set,
    /// Output stayed at logic 0.
    Held,
    /// Current reached I_RESET — electrically erroneous.
    ResetViolation,
}

/// Report of one TMVM step.
#[derive(Clone, Debug)]
pub struct TmvmReport {
    /// Thresholded output bits (one per row).
    pub outputs: Vec<bool>,
    /// Final output-cell current per row \[A\].
    pub currents: Vec<f64>,
    /// Per-row outcome classification.
    pub outcomes: Vec<TmvmOutcome>,
    /// Applied voltage.
    pub v_dd: f64,
    /// Energy booked for this step \[J\].
    pub energy: f64,
}

impl TmvmReport {
    /// Any electrical violations?
    pub fn is_clean(&self) -> bool {
        !self
            .outcomes
            .iter()
            .any(|o| matches!(o, TmvmOutcome::ResetViolation))
    }
}

impl Subarray {
    /// Execute one TMVM step: inputs (one bit per column) against the top
    /// level, thresholded results written to bottom-level column
    /// `out_col`. The output column is preset first (pipelined).
    pub fn tmvm(&mut self, inputs: &[bool], out_col: usize, v_dd: f64, mode: TmvmMode) -> TmvmReport {
        let n_row = self.n_row();
        self.tmvm_rows(inputs, out_col, v_dd, mode, n_row)
    }

    /// [`Subarray::tmvm`] restricted to the first `active_rows` rows: the
    /// WLBs of the remaining rows are floated (paper Fig. 4(b), cells "not
    /// engaged in the computation"), so they carry no current and burn no
    /// energy. The coordinator uses this when a batch only fills part of
    /// the subarray.
    ///
    /// In [`TmvmMode::Ideal`] this takes the packed popcount fast path
    /// (row sums from `count_ones` over the top level's `u64` shadow —
    /// the full report, violations included, derives from the counts);
    /// [`TmvmMode::Parasitic`] needs the per-cell electrical walk and
    /// falls back to [`Subarray::tmvm_rows_scalar`].
    pub fn tmvm_rows(
        &mut self,
        inputs: &[bool],
        out_col: usize,
        v_dd: f64,
        mode: TmvmMode,
        active_rows: usize,
    ) -> TmvmReport {
        match mode {
            TmvmMode::Ideal => self.tmvm_rows_ideal_packed(inputs, out_col, v_dd, active_rows),
            TmvmMode::Parasitic => self.tmvm_rows_scalar(inputs, out_col, v_dd, mode, active_rows),
        }
    }

    /// The ideal-mode popcount hot path: one `AND + count_ones` pass per
    /// lane instead of a conductance sum per cell. Bit-exact in outputs
    /// and outcomes with [`Subarray::tmvm_rows_scalar`] (pinned by
    /// `tests/prop_packed.rs`); currents agree to f64 rounding because
    /// the count-space conductance sum reassociates the addition.
    fn tmvm_rows_ideal_packed(
        &mut self,
        inputs: &[bool],
        out_col: usize,
        v_dd: f64,
        active_rows: usize,
    ) -> TmvmReport {
        assert_eq!(inputs.len(), self.n_col(), "one input bit per column");
        assert!(out_col < self.n_col());
        assert!(v_dd > 0.0);
        assert!(active_rows <= self.n_row());
        let p = self.design().device;

        self.preset_output_column(out_col, true);

        let x = BitVec::from_bools(inputs);
        let active = x.count_ones();
        let n_row = self.n_row();
        let mut outputs = Vec::with_capacity(n_row);
        let mut currents = Vec::with_capacity(n_row);
        let mut outcomes = Vec::with_capacity(n_row);
        let mut current_sum = 0.0;

        for row in 0..n_row {
            if row >= active_rows {
                // floated WLB: no current path through this row
                self.force_bottom(row, out_col, false);
                outputs.push(false);
                currents.push(0.0);
                outcomes.push(TmvmOutcome::Held);
                continue;
            }
            let count = and_count(self.top_row_words(row), x.words());
            let i_t = ideal_row_current(count, active, v_dd, &p);
            let (bit, outcome) = if i_t >= p.i_reset {
                (false, TmvmOutcome::ResetViolation)
            } else if i_t >= p.i_set {
                (true, TmvmOutcome::Set)
            } else {
                (false, TmvmOutcome::Held)
            };
            self.force_bottom(row, out_col, bit);
            outputs.push(bit);
            currents.push(i_t);
            outcomes.push(outcome);
            current_sum += i_t;
        }

        let e_before = self.ledger.energy;
        self.ledger.book_step(v_dd, current_sum, p.t_set);
        TmvmReport {
            outputs,
            currents,
            outcomes,
            v_dd,
            energy: self.ledger.energy - e_before,
        }
    }

    /// The per-cell electrical walk — the **reference oracle** for the
    /// packed path, and the only implementation of the parasitic ladder
    /// model. Handles both modes; kept public so property tests and the
    /// benches can pit the packed path against it on the same subarray.
    pub fn tmvm_rows_scalar(
        &mut self,
        inputs: &[bool],
        out_col: usize,
        v_dd: f64,
        mode: TmvmMode,
        active_rows: usize,
    ) -> TmvmReport {
        assert_eq!(inputs.len(), self.n_col(), "one input bit per column");
        assert!(out_col < self.n_col());
        assert!(v_dd > 0.0);
        assert!(active_rows <= self.n_row());
        let design = self.design().clone();
        let p = design.device;

        self.preset_output_column(out_col, true);

        // Parasitic mode: per-row Thevenin (α, R_th), computed once per
        // subarray and cached (the geometry never changes). The ladder
        // model's r_th already contains the victim bit-line span; α
        // multiplies the delivered voltage.
        if matches!(mode, TmvmMode::Parasitic) && self.thevenin_cache.is_none() {
            self.thevenin_cache = Some(
                (1..=design.n_row)
                    .map(|row| ladder_thevenin(&design, row))
                    .collect(),
            );
        }
        let n_row = design.n_row;
        let mut outputs = Vec::with_capacity(n_row);
        let mut currents = Vec::with_capacity(n_row);
        let mut outcomes = Vec::with_capacity(n_row);
        let mut current_sum = 0.0;

        for row in 0..n_row {
            if row >= active_rows {
                // floated WLB: no current path through this row
                self.force_bottom(row, out_col, false);
                outputs.push(false);
                currents.push(0.0);
                outcomes.push(TmvmOutcome::Held);
                continue;
            }
            // conductance sum over engaged inputs (floated lines drop out)
            let mut g_sum = 0.0;
            for (col, &x) in inputs.iter().enumerate() {
                if x {
                    g_sum += self.top_conductance(row, col);
                }
            }
            let i_t = if g_sum == 0.0 {
                0.0
            } else {
                match mode {
                    TmvmMode::Ideal => {
                        // Eq. 3 at the crystalline endpoint (G_O = G_C)
                        p.g_c * v_dd * g_sum / (g_sum + p.g_c)
                    }
                    TmvmMode::Parasitic => {
                        let th = self.thevenin_cache.as_ref().expect("cache primed")[row];
                        // wire Thevenin drives input network + output cell
                        let r_path = th.r_th + 1.0 / g_sum + 1.0 / p.g_c;
                        th.alpha * v_dd / r_path
                    }
                }
            };
            let (bit, outcome) = if i_t >= p.i_reset {
                // accidental RESET: the cell melts back to amorphous
                (false, TmvmOutcome::ResetViolation)
            } else if i_t >= p.i_set {
                (true, TmvmOutcome::Set)
            } else {
                (false, TmvmOutcome::Held)
            };
            self.force_bottom(row, out_col, bit);
            outputs.push(bit);
            currents.push(i_t);
            outcomes.push(outcome);
            current_sum += i_t;
        }

        let e_before = self.ledger.energy;
        self.ledger.book_step(v_dd, current_sum, p.t_set);
        TmvmReport {
            outputs,
            currents,
            outcomes,
            v_dd,
            energy: self.ledger.energy - e_before,
        }
    }

    /// The operating voltage that realizes an integer firing threshold
    /// `theta` (delegates to [`DeviceParams::vdd_for_threshold`]).
    ///
    /// [`DeviceParams::vdd_for_threshold`]: crate::device::DeviceParams::vdd_for_threshold
    pub fn vdd_for_threshold(&self, theta: usize) -> f64 {
        self.design().device.vdd_for_threshold(theta)
    }

    /// The integer firing threshold realized by `v_dd` (ideal mode):
    /// smallest count n₁ of crystalline products with `I_T ≥ I_SET`.
    pub fn threshold_for_vdd(&self, v_dd: f64) -> Option<usize> {
        let p = self.design().device;
        if v_dd * p.g_c <= p.i_set {
            return None; // can never fire
        }
        // n·G_C/(n·G_C + G_C)·V·G_C ≥ I_SET  ⇔  n ≥ I_SET/(V·G_C − I_SET)
        // (tiny slack keeps the exact boundary on the firing side despite
        // floating-point rounding, matching the ≥ comparison in `tmvm`)
        let n = p.i_set / (v_dd * p.g_c - p.i_set);
        Some((n - 1e-9).ceil().max(1.0) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ArrayDesign;
    use crate::array::Level;
    use crate::interconnect::LineConfig;

    fn array(n_row: usize, n_col: usize) -> Subarray {
        Subarray::new(ArrayDesign::new(n_row, n_col, LineConfig::config3(), 3.0, 1.0))
    }

    /// Program an identity-ish pattern and verify single-input selection.
    #[test]
    fn identity_matrix_selects_inputs() {
        let n = 6;
        let mut sa = array(n, n);
        let eye: Vec<Vec<bool>> = (0..n).map(|r| (0..n).map(|c| r == c).collect()).collect();
        sa.program_level(Level::Top, &eye);
        // θ = 1: fire on a single crystalline product
        let v = sa.vdd_for_threshold(1);
        for active in 0..n {
            let mut x = vec![false; n];
            x[active] = true;
            let rep = sa.tmvm(&x, 0, v, TmvmMode::Ideal);
            assert!(rep.is_clean());
            for r in 0..n {
                assert_eq!(rep.outputs[r], r == active, "row {r}, active {active}");
            }
        }
    }

    #[test]
    fn threshold_voltage_roundtrip() {
        let sa = array(4, 8);
        for theta in 1..=8 {
            let v = sa.vdd_for_threshold(theta);
            assert_eq!(sa.threshold_for_vdd(v), Some(theta), "theta {theta}");
            // marginally above the boundary still realizes θ; marginally
            // below demands one more active product
            assert_eq!(sa.threshold_for_vdd(v * 1.001), Some(theta));
            assert_eq!(sa.threshold_for_vdd(v * 0.999), Some(theta + 1));
        }
        assert_eq!(sa.threshold_for_vdd(1e-6), None);
    }

    #[test]
    fn counts_threshold_semantics() {
        let n_col = 12;
        let mut sa = array(3, n_col);
        // row 0: 3 ones, row 1: 5 ones, row 2: 8 ones
        let mut bits = vec![vec![false; n_col]; 3];
        for c in 0..3 {
            bits[0][c] = true;
        }
        for c in 0..5 {
            bits[1][c] = true;
        }
        for c in 0..8 {
            bits[2][c] = true;
        }
        sa.program_level(Level::Top, &bits);
        let x = vec![true; n_col]; // all inputs active
        let v = sa.vdd_for_threshold(5);
        let rep = sa.tmvm(&x, 1, v, TmvmMode::Ideal);
        assert_eq!(rep.outputs, vec![false, true, true]);
        // outputs are stored in the requested bottom column
        assert!(!sa.peek(Level::Bottom, 0, 1));
        assert!(sa.peek(Level::Bottom, 1, 1));
        assert!(sa.peek(Level::Bottom, 2, 1));
    }

    #[test]
    fn excessive_voltage_flags_reset_violation() {
        let n_col = 8;
        let mut sa = array(2, n_col);
        sa.program_level(Level::Top, &vec![vec![true; n_col]; 2]);
        // far above the ideal window: I_T > I_RESET
        let rep = sa.tmvm(&vec![true; n_col], 0, 5.0, TmvmMode::Ideal);
        assert!(!rep.is_clean());
        assert!(rep
            .outcomes
            .iter()
            .all(|o| matches!(o, TmvmOutcome::ResetViolation)));
    }

    #[test]
    fn parasitic_mode_weakens_far_rows() {
        // A tall skinny array at marginal voltage: the ideal mode fires all
        // rows; the parasitic mode loses the far rows first.
        let n_row = 2048;
        let mut sa = Subarray::new(
            ArrayDesign::new(n_row, 8, LineConfig::config1(), 1.0, 1.0).with_driver(1.0),
        );
        sa.program_level(Level::Top, &vec![vec![true; 8]; n_row]);
        let x = vec![true; 8];
        let v = sa.vdd_for_threshold(8) * 1.10; // modest margin
        let ideal = sa.tmvm(&x, 0, v, TmvmMode::Ideal);
        assert!(ideal.outputs.iter().all(|&b| b), "ideal fires everywhere");
        let para = sa.tmvm(&x, 0, v, TmvmMode::Parasitic);
        assert!(para.outputs[0], "first row still fires");
        assert!(
            !para.outputs[n_row - 1],
            "last row starved by the wire drop"
        );
        // currents must be monotonically non-increasing with row depth
        for w in para.currents.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn floated_inputs_draw_no_current() {
        let mut sa = array(3, 4);
        sa.program_level(Level::Top, &vec![vec![true; 4]; 3]);
        let rep = sa.tmvm(&vec![false; 4], 0, 0.9, TmvmMode::Ideal);
        assert!(rep.currents.iter().all(|&i| i == 0.0));
        assert!(rep.outputs.iter().all(|&b| !b));
    }

    #[test]
    fn amorphous_weights_leak_negligibly() {
        // all inputs driven, all weights 0: currents ≪ I_SET (this is the
        // R2 condition of Eq. 5)
        let mut sa = array(2, 121);
        let p = sa.design().device;
        let rep = sa.tmvm(&vec![true; 121], 0, 0.9, TmvmMode::Ideal);
        assert!(rep.outputs.iter().all(|&b| !b));
        assert!(rep.currents.iter().all(|&i| i < p.i_set));
        assert!(rep.currents[0] > 0.0, "leakage is nonzero");
    }

    #[test]
    fn packed_ideal_path_matches_scalar_oracle() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(77);
        let shapes = [(5usize, 7usize, 5usize), (8, 64, 6), (6, 65, 3), (4, 121, 4)];
        for &(n_row, n_col, active_rows) in &shapes {
            let mut fast = array(n_row, n_col);
            let mut oracle = array(n_row, n_col);
            let bits: Vec<Vec<bool>> = (0..n_row)
                .map(|_| (0..n_col).map(|_| rng.bernoulli(0.5)).collect())
                .collect();
            fast.program_level(Level::Top, &bits);
            oracle.program_level(Level::Top, &bits);
            let x: Vec<bool> = (0..n_col).map(|_| rng.bernoulli(0.6)).collect();
            let v = fast.vdd_for_threshold(3);
            let a = fast.tmvm_rows(&x, 0, v, TmvmMode::Ideal, active_rows);
            let b = oracle.tmvm_rows_scalar(&x, 0, v, TmvmMode::Ideal, active_rows);
            assert_eq!(a.outputs, b.outputs, "{n_row}x{n_col}");
            assert_eq!(a.outcomes, b.outcomes);
            for (ia, ib) in a.currents.iter().zip(&b.currents) {
                assert!((ia - ib).abs() <= 1e-12 * ib.abs() + 1e-18);
            }
            assert!((a.energy - b.energy).abs() <= 1e-9 * b.energy.abs() + 1e-24);
        }
    }

    #[test]
    fn step_energy_in_picojoule_regime() {
        let mut sa = array(10, 121);
        sa.program_level(Level::Top, &vec![vec![true; 121]; 10]);
        let v = sa.vdd_for_threshold(60);
        let rep = sa.tmvm(&vec![true; 121], 0, v, TmvmMode::Ideal);
        // 10 output rows ≈ tens of pJ total (Table II: ~21.5 pJ/image for
        // P = 10 outputs)
        assert!(
            rep.energy > 1e-12 && rep.energy < 100e-12,
            "E = {} J",
            rep.energy
        );
    }
}

//! Multi-subarray scaling (paper §IV-B, Fig. 6, supplementary Table VII):
//! switch fabrics connecting subarrays and tiling of large operands.

pub mod interlink;
pub mod tiling;

pub use interlink::{LineGroup, LineState, LinkConfig, LinkedPair};
pub use tiling::{TileAssignment, Tiling};

//! Ideal (parasitic-free) operating-voltage windows — paper §III-A,
//! Eqs. (4) and (5).

use crate::device::DeviceParams;

/// The ideal acceptable `V_DD` window `R1 ∩ R2` for a TMVM over
/// `n_inputs = N_x + 1` engaged inputs.
#[derive(Clone, Copy, Debug)]
pub struct IdealWindow {
    /// `min(R1)` — lowest voltage that still completes a SET when all
    /// inputs/weights are 1.
    pub r1_min: f64,
    /// `max(R1)` — highest voltage that avoids an accidental RESET.
    pub r1_max: f64,
    /// `max(R2)` — highest voltage that cannot flip a logic-0 result.
    pub r2_max: f64,
}

impl IdealWindow {
    /// Lower edge of the acceptable window `V_min = min(R1)`.
    pub fn v_min(&self) -> f64 {
        self.r1_min
    }

    /// Upper edge `V_max = min(max(R1), max(R2))`.
    pub fn v_max(&self) -> f64 {
        self.r1_max.min(self.r2_max)
    }

    /// Is the window non-empty?
    pub fn is_valid(&self) -> bool {
        self.v_min() <= self.v_max()
    }

    /// Window midpoint — the natural operating voltage.
    pub fn v_mid(&self) -> f64 {
        0.5 * (self.v_min() + self.v_max())
    }

    /// Ideal noise margin of the window (Eq. 7 with no parasitic shift).
    pub fn noise_margin(&self) -> f64 {
        (self.v_max() - self.v_min()) / self.v_mid()
    }
}

/// Compute the ideal window for `n_inputs` engaged inputs (paper's
/// `N_x + 1`).
///
/// Eq. (4): `R1 = [(Nx+2)/(Nx+1) · I_SET/G_C, (Nx+2)/(Nx+1) · I_RESET/G_C]`
/// — all inputs and weights at logic 1; the output-cell current must reach
/// `I_SET` but stay below `I_RESET`.
///
/// Eq. (5): `R2 = [0, ((Nx+1)·G_A + G_C)/((Nx+1)·G_A·G_C) · I_SET]` — all
/// weights at logic 0; the output must *not* flip.
pub fn ideal_window(n_inputs: usize, p: &DeviceParams) -> IdealWindow {
    assert!(n_inputs >= 1);
    let n1 = n_inputs as f64; // N_x + 1
    let n2 = n1 + 1.0; // N_x + 2
    let factor = n2 / n1;
    IdealWindow {
        r1_min: factor * p.i_set / p.g_c,
        r1_max: factor * p.i_reset / p.g_c,
        r2_max: (n1 * p.g_a + p.g_c) / (n1 * p.g_a * p.g_c) * p.i_set,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> DeviceParams {
        DeviceParams::default()
    }

    #[test]
    fn hand_computed_values_121_inputs() {
        // Nx+1 = 121 (an 11×11 image): factor = 122/121,
        // r1_min = 122/121 · 50µA/160µS ≈ 0.3151 V
        // r2_max = (121·660n + 160µ)/(121·660n·160µ) · 50µA ≈ 0.9384 V
        let w = ideal_window(121, &p());
        assert!((w.r1_min - 0.3151).abs() < 1e-3, "r1_min {}", w.r1_min);
        assert!((w.r1_max - 0.6302).abs() < 1e-3, "r1_max {}", w.r1_max);
        assert!((w.r2_max - 0.9384).abs() < 1e-3, "r2_max {}", w.r2_max);
        assert!(w.is_valid());
        // upper edge governed by R1 (avoid accidental RESET), not R2
        assert!((w.v_max() - w.r1_max).abs() < 1e-12);
    }

    #[test]
    fn single_input_window() {
        // Nx+1 = 1: factor = 2 ⇒ v_min = 2·I_SET/G_C = 0.625 V,
        // r1_max = 1.25 V; r2_max = I_SET·(1/G_C + 1/G_A) ≈ 76 V (huge).
        let w = ideal_window(1, &p());
        assert!((w.v_min() - 0.625).abs() < 1e-9);
        assert!((w.v_max() - 1.25).abs() < 1e-9);
        assert!(w.r2_max > 50.0);
        // ideal NM of the corner case = (1.25-0.625)/0.9375 = 2/3
        assert!((w.noise_margin() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn window_tightens_then_saturates_with_inputs() {
        // v_min decreases toward I_SET/G_C as more inputs share the load;
        // r2_max decreases with inputs (more amorphous leakage paths).
        let w8 = ideal_window(8, &p());
        let w1024 = ideal_window(1024, &p());
        assert!(w1024.v_min() < w8.v_min());
        assert!(w1024.r2_max < w8.r2_max);
        assert!(w1024.is_valid());
    }

    #[test]
    fn noise_margin_vanishes_for_huge_fanin() {
        // For n ≫ G_C/G_A the upper edge is R2-governed and the window
        // width shrinks like 1/n: the ideal NM tends to zero even before
        // parasitics enter. (r1_min stays strictly below r2_max for the
        // paper's parameters, so the window never fully inverts.)
        let nm_small = ideal_window(121, &p()).noise_margin();
        let nm_big = ideal_window(1 << 20, &p()).noise_margin();
        assert!(nm_big < nm_small / 100.0, "nm_big = {nm_big}");
        assert!(ideal_window(1 << 20, &p()).is_valid());
        // beyond the conductance ratio the upper edge switches to R2
        let w = ideal_window(1024, &p());
        assert!((w.v_max() - w.r2_max).abs() < 1e-12);
    }
}

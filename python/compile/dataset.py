"""Synthetic 11x11 digit dataset - python twin of rust/src/nn/dataset.rs.

The generator consumes a SplitMix64 stream in a fixed draw order (label,
dx, dy, then 121 noise draws in row-major pixel order) so that the rust
simulator and this compile path see BIT-IDENTICAL data for a given seed.
Keep the glyphs and the draw order in sync with the rust module.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

IMAGE_SIDE = 11
IMAGE_PIXELS = IMAGE_SIDE * IMAGE_SIDE
N_CLASSES = 10

# The canonical test corpus seed shared with rust (nn::dataset::TEST_SEED).
TEST_SEED = 0x3D_C0FFEE

MASK64 = (1 << 64) - 1

# Mirrored verbatim from rust/src/nn/dataset.rs::GLYPHS.
GLYPHS = [
    [
        "...#####...",
        "..##...##..",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        "..##...##..",
        "...#####...",
    ],
    [
        ".....##....",
        "....###....",
        "...####....",
        ".....##....",
        ".....##....",
        ".....##....",
        ".....##....",
        ".....##....",
        ".....##....",
        "...######..",
        "...######..",
    ],
    [
        "..######...",
        ".##....##..",
        ".......##..",
        ".......##..",
        "......##...",
        ".....##....",
        "....##.....",
        "...##......",
        "..##.......",
        ".#########.",
        ".#########.",
    ],
    [
        "..######...",
        ".##....##..",
        ".......##..",
        ".......##..",
        "...#####...",
        "...#####...",
        ".......##..",
        ".......##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        ".....###...",
        "....####...",
        "...##.##...",
        "..##..##...",
        ".##...##...",
        ".#########.",
        ".#########.",
        "......##...",
        "......##...",
        "......##...",
        "...........",
    ],
    [
        ".########..",
        ".##........",
        ".##........",
        ".##........",
        ".#######...",
        ".......##..",
        ".......##..",
        ".......##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        "...#####...",
        "..##.......",
        ".##........",
        ".##........",
        ".#######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        ".#########.",
        ".#########.",
        ".......##..",
        "......##...",
        ".....##....",
        ".....##....",
        "....##.....",
        "....##.....",
        "...##......",
        "...##......",
        "...........",
    ],
    [
        "..######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        "..######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..#######..",
        ".......##..",
        ".......##..",
        "......##...",
        "..#####....",
        "...........",
    ],
]


class SplitMix64:
    """Bit-identical twin of rust/src/util/prng.rs::SplitMix64."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_below(self, bound: int) -> int:
        return (self.next_u64() * bound) >> 64


@dataclass
class Sample:
    pixels: np.ndarray  # (121,) uint8 in {0,1}, row-major
    label: int


class DigitGen:
    """Deterministic digit generator (twin of rust nn::dataset::DigitGen)."""

    def __init__(self, seed: int, noise: float = 0.02):
        self.stream = SplitMix64(seed)
        self.noise = noise

    @staticmethod
    def template_pixel(label: int, y: int, x: int) -> bool:
        return GLYPHS[label][y][x] == "#"

    def next_sample(self) -> Sample:
        label = self.stream.next_below(N_CLASSES)
        dx = self.stream.next_below(3) - 1
        dy = self.stream.next_below(3) - 1
        pixels = np.zeros(IMAGE_PIXELS, dtype=np.uint8)
        i = 0
        for y in range(IMAGE_SIDE):
            for x in range(IMAGE_SIDE):
                sy, sx = y - dy, x - dx
                base = (
                    0 <= sy < IMAGE_SIDE
                    and 0 <= sx < IMAGE_SIDE
                    and self.template_pixel(label, sy, sx)
                )
                flip = self.stream.next_f64() < self.noise
                pixels[i] = 1 if (base ^ flip) else 0
                i += 1
        return Sample(pixels=pixels, label=label)

    def dataset(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Return (X, y): X (n, 121) float32 in {0,1}; y (n,) int32."""
        xs = np.zeros((n, IMAGE_PIXELS), dtype=np.float32)
        ys = np.zeros(n, dtype=np.int32)
        for i in range(n):
            s = self.next_sample()
            xs[i] = s.pixels
            ys[i] = s.label
        return xs, ys

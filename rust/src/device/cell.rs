//! Composite crosspoint cell: PCM storage element in series with an OTS
//! selector (paper Fig. 2(b)).

use super::ots::Ots;
use super::params::DeviceParams;
use super::pcm::PcmCell;

/// One crosspoint: PCM + OTS in series between a word line and a bit line.
#[derive(Clone, Debug, Default)]
pub struct XPointCell {
    pub pcm: PcmCell,
    pub ots: Ots,
}

impl XPointCell {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_bit(bit: bool) -> Self {
        Self {
            pcm: PcmCell::with_bit(bit),
            ots: Ots,
        }
    }

    /// Series conductance of the selected (OTS on) cell at small signal.
    ///
    /// With `G_on = 10 S`, the OTS contributes ~0.1 Ω — negligible against
    /// the PCM's kΩ–MΩ, so the selected-cell conductance is effectively the
    /// PCM conductance (this is why the paper's Eq. 3 uses `G_{i,j}`
    /// directly).
    pub fn selected_conductance(&self, p: &DeviceParams) -> f64 {
        series(self.pcm.conductance(p), p.ots_g_on)
    }

    /// Series conductance of an unselected (OTS off) cell — the sneak-path
    /// leak.
    pub fn unselected_conductance(&self, p: &DeviceParams) -> f64 {
        series(self.pcm.conductance(p), p.ots_g_off)
    }

    /// Effective conductance at a given bias across the whole cell.
    pub fn conductance_at(&self, p: &DeviceParams, v_across: f64) -> f64 {
        // Voltage divides across OTS and PCM; approximate the OTS decision
        // with the full cell bias (the OTS takes nearly all of it when OFF).
        let g_ots = self.ots.conductance(p, v_across);
        series(self.pcm.dynamic_conductance(p, v_across), g_ots)
    }

    /// Stored logic bit.
    pub fn bit(&self) -> bool {
        self.pcm.bit()
    }

    /// Ideal write.
    pub fn write_bit(&mut self, bit: bool) {
        self.pcm.write_bit(bit);
    }
}

/// Series combination of two conductances.
pub fn series(g1: f64, g2: f64) -> f64 {
    if g1 == 0.0 || g2 == 0.0 {
        0.0
    } else {
        g1 * g2 / (g1 + g2)
    }
}

/// Parallel combination of two conductances.
pub fn parallel(g1: f64, g2: f64) -> f64 {
    g1 + g2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selected_cell_is_pcm_dominated() {
        let p = DeviceParams::default();
        let c = XPointCell::with_bit(true);
        let g = c.selected_conductance(&p);
        assert!((g - p.g_c).abs() / p.g_c < 1e-4, "OTS-on ~ transparent");
    }

    #[test]
    fn unselected_cell_is_ots_dominated() {
        let p = DeviceParams::default();
        let c = XPointCell::with_bit(true);
        let g = c.unselected_conductance(&p);
        assert!((g - p.ots_g_off).abs() / p.ots_g_off < 1e-2);
        assert!(g < 1e-3 * c.selected_conductance(&p));
    }

    #[test]
    fn series_parallel_identities() {
        assert_eq!(series(0.0, 5.0), 0.0);
        assert!((series(2.0, 2.0) - 1.0).abs() < 1e-12);
        assert!((parallel(2.0, 3.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn bias_gates_conduction() {
        let p = DeviceParams::default();
        let c = XPointCell::with_bit(true);
        let g_off = c.conductance_at(&p, 0.1);
        let g_on = c.conductance_at(&p, 0.5);
        assert!(g_on / g_off > 1e3, "selector gating");
    }
}

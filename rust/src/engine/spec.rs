//! [`EngineSpec`] — one declarative description of the accelerator at any
//! fidelity, and the registry that turns it into running engines.
//!
//! A spec unifies everything the old ad-hoc entry points took separately:
//! the subarray design ([`ArraySpec`]), the fabric geometry
//! ([`FabricSpec`]), the batching policy ([`BatchPolicy`]), the network
//! source and the backend kind. It is constructible three ways:
//!
//! * **from code** — builder style: `EngineSpec::new(BackendKind::Fabric)
//!   .with_grid(4, 4).with_layers(layers)`;
//! * **from CLI flags** — [`EngineSpec::from_args`] (the `xpoint serve`
//!   surface: `--fabric`, `--xla`, `--parasitic`, `--grid`, `--batch`,
//!   `--workers`, with conflicts rejected as typed [`EngineError`]s);
//! * **from a JSON file** — [`EngineSpec::from_json_file`] (`--engine
//!   path.json`), with [`EngineSpec::to_json`] as the inverse.
//!
//! [`EngineSpec::build`] is the single construction path for every
//! backend: it validates eagerly on the calling thread and returns a
//! [`BackendFactory`] that the coordinator runs on a worker thread.

use std::path::Path;
use std::time::Duration;

use super::api::{BackendFactory, Engine};
use super::backends::{FabricBackend, SimBackend, XlaBackend, XLA_GRAPH_BATCH};
use super::error::EngineError;
use super::sharded::{ShardBuilder, ShardedEngine};
use crate::analysis::ArrayDesign;
use crate::array::multibit::{multibit_tmvm_cost, MultibitCost, MultibitScheme, V_CEILING};
use crate::array::TmvmMode;
use crate::cli::Args;
use crate::coordinator::autoscale::AutoscalePolicy;
use crate::coordinator::CoordinatorConfig;
use crate::fabric::{place_layers, FabricConfig, PlacementStrategy};
use crate::interconnect::LineConfig;
use crate::net::{remote_factory, RemoteAddr};
use crate::nn::BinaryLayer;
use crate::runtime::{ArtifactStore, Runtime};
use crate::util::json::Json;

/// Backend fidelity: which model of the substrate serves the requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Single subarray, ideal Eq. 3 TMVM (no wire parasitics).
    Ideal,
    /// Single subarray with the Appendix-A parasitic ladder model.
    Parasitic,
    /// Event-driven multi-subarray fabric (tiled, pipelined).
    Fabric,
    /// AOT-compiled XLA golden model on the PJRT CPU client.
    Xla,
    /// N independent shards of [`ShardSpec::inner`], each on its own
    /// worker thread behind an asynchronous least-loaded scheduler
    /// ([`ShardedEngine`]). Configured by [`EngineSpec::sharding`].
    Sharded,
    /// One shard's worth of fabric served by a remote `xpoint
    /// shard-host` process, spoken to over TCP or a Unix socket
    /// ([`RemoteBackend`](crate::net::RemoteBackend)). Configured by
    /// [`EngineSpec::remote`] (`--remote host:port|unix:/path`).
    Remote,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            Self::Ideal => "ideal",
            Self::Parasitic => "parasitic",
            Self::Fabric => "fabric",
            Self::Xla => "xla",
            Self::Sharded => "sharded",
            Self::Remote => "remote",
        }
    }

    pub fn parse(s: &str) -> Result<Self, EngineError> {
        match s.to_ascii_lowercase().as_str() {
            "ideal" => Ok(Self::Ideal),
            "parasitic" => Ok(Self::Parasitic),
            "fabric" => Ok(Self::Fabric),
            "xla" => Ok(Self::Xla),
            "sharded" => Ok(Self::Sharded),
            "remote" => Ok(Self::Remote),
            _ => Err(EngineError::UnknownBackend(s.to_string())),
        }
    }
}

/// Sharding section of the spec: how many shards and what each shard is.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardSpec {
    /// Independent engine shards (each is one full inner backend).
    pub shards: usize,
    /// The backend each shard runs. Must itself be non-sharded; `Xla` is
    /// rejected (PJRT clients are thread-affine — scale it with workers).
    pub inner: BackendKind,
    /// Canary sampling fraction (`--canary F`). Non-zero adds one extra
    /// parasitic-fidelity shard that never takes primary traffic; the
    /// scheduler mirrors this fraction of submissions onto it and counts
    /// fidelity divergences ([`Engine::canary_report`]). 0 = no canary.
    pub canary: f64,
}

impl Default for ShardSpec {
    fn default() -> Self {
        Self {
            shards: 2,
            inner: BackendKind::Ideal,
            canary: 0.0,
        }
    }
}

impl ShardSpec {
    fn from_json(v: &Json) -> Result<Self, EngineError> {
        let entries = obj_entries(v, "sharding")?;
        let mut spec = Self::default();
        for (key, val) in entries {
            match key.as_str() {
                "shards" => spec.shards = json_usize(val, "sharding.shards")?,
                "inner" => spec.inner = BackendKind::parse(json_str(val, "sharding.inner")?)?,
                "canary" => spec.canary = json_f64(val, "sharding.canary")?,
                other => {
                    return Err(EngineError::Json(format!("unknown field 'sharding.{other}'")))
                }
            }
        }
        Ok(spec)
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("shards".into(), Json::Num(self.shards as f64)),
            ("inner".into(), Json::Str(self.inner.name().into())),
            ("canary".into(), Json::Num(self.canary)),
        ])
    }
}

/// Remote-fleet section of the spec: shard-host endpoints and socket
/// timeouts. Empty `addrs` (the default) means an all-local fleet; for
/// the `Remote` backend exactly one address drives the whole engine; for
/// `Sharded` every address joins the fleet as one extra shard next to
/// the local ones (`--remote host:port,unix:/path`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RemoteSpec {
    /// Shard-host endpoints (`host:port` or `unix:/path`).
    pub addrs: Vec<String>,
    /// How long a connect attempt may retry before giving up \[ms\].
    pub connect_timeout_ms: u64,
    /// Per-call socket read/write deadline \[ms\].
    pub io_timeout_ms: u64,
}

impl Default for RemoteSpec {
    fn default() -> Self {
        Self {
            addrs: Vec::new(),
            connect_timeout_ms: 5_000,
            io_timeout_ms: 30_000,
        }
    }
}

impl RemoteSpec {
    pub fn connect_timeout(&self) -> Duration {
        Duration::from_millis(self.connect_timeout_ms)
    }

    pub fn io_timeout(&self) -> Duration {
        Duration::from_millis(self.io_timeout_ms)
    }

    pub fn validate(&self) -> Result<(), EngineError> {
        for addr in &self.addrs {
            RemoteAddr::parse(addr)?;
        }
        if self.connect_timeout_ms == 0 || self.io_timeout_ms == 0 {
            return Err(EngineError::Spec {
                field: "remote",
                detail: format!(
                    "socket timeouts must be at least 1 ms, got connect={} io={}",
                    self.connect_timeout_ms, self.io_timeout_ms
                ),
            });
        }
        Ok(())
    }

    fn from_json(v: &Json) -> Result<Self, EngineError> {
        let entries = obj_entries(v, "remote")?;
        let mut spec = Self::default();
        for (key, val) in entries {
            match key.as_str() {
                "addrs" => {
                    let items = match val {
                        Json::Arr(items) => items,
                        _ => {
                            return Err(EngineError::Json(
                                "field 'remote.addrs': expected an array of strings".into(),
                            ))
                        }
                    };
                    spec.addrs = items
                        .iter()
                        .map(|a| json_str(a, "remote.addrs").map(String::from))
                        .collect::<Result<_, _>>()?;
                }
                "connect_timeout_ms" => {
                    spec.connect_timeout_ms =
                        json_usize(val, "remote.connect_timeout_ms")? as u64
                }
                "io_timeout_ms" => {
                    spec.io_timeout_ms = json_usize(val, "remote.io_timeout_ms")? as u64
                }
                other => {
                    return Err(EngineError::Json(format!("unknown field 'remote.{other}'")))
                }
            }
        }
        Ok(spec)
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "addrs".into(),
                Json::Arr(self.addrs.iter().map(|a| Json::Str(a.clone())).collect()),
            ),
            (
                "connect_timeout_ms".into(),
                Json::Num(self.connect_timeout_ms as f64),
            ),
            ("io_timeout_ms".into(), Json::Num(self.io_timeout_ms as f64)),
        ])
    }
}

/// Autoscaling section of the spec: queue-driven elastic shard lifecycle
/// (the `Sharded` backend grows and shrinks its fleet between
/// `min_shards` and `max_shards` as backlog crosses the watermarks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AutoscaleSpec {
    /// Serving shards the engine starts with and never drops below.
    pub min_shards: usize,
    /// Serving shards the policy never exceeds.
    pub max_shards: usize,
    /// Backlog (queued + in-flight images) per serving shard above which
    /// the policy spawns a shard.
    pub high_watermark: usize,
    /// Backlog per serving shard below which the policy retires one.
    pub low_watermark: usize,
    /// Policy evaluations that must pass between consecutive scale
    /// events (hysteresis against flapping).
    pub cooldown: u64,
    /// Per-shard pulse-endurance budget (0 = unlimited): cumulative
    /// SET/RESET pulses a slot may absorb across its lifetime; spawns
    /// that would push a slot past it are vetoed.
    pub pulse_budget: u64,
}

impl Default for AutoscaleSpec {
    fn default() -> Self {
        Self {
            min_shards: 1,
            max_shards: 4,
            high_watermark: 96,
            low_watermark: 16,
            cooldown: 2,
            pulse_budget: 0,
        }
    }
}

impl AutoscaleSpec {
    /// The serve-path policy for a coordinator batch capacity: spawn
    /// above ~1.5 waiting batches per serving shard, retire below a
    /// quarter batch. One formula, shared by `--autoscale` and the
    /// `xpoint autoscale` exhibit, so they replay the same policy.
    pub fn for_batch(min_shards: usize, max_shards: usize, batch_capacity: usize) -> Self {
        let cap = batch_capacity.max(1);
        let low = (cap / 4).max(1);
        Self {
            min_shards,
            max_shards,
            // tiny capacities would collapse the band (cap=1 → high ==
            // low == 1); keep the watermarks strictly ordered
            high_watermark: (cap + cap / 2).max(low + 1),
            low_watermark: low,
            ..Self::default()
        }
    }

    pub fn validate(&self) -> Result<(), EngineError> {
        if self.min_shards == 0 {
            return Err(EngineError::ZeroShards);
        }
        if self.min_shards > self.max_shards {
            return Err(EngineError::Spec {
                field: "autoscale",
                detail: format!(
                    "min_shards {} exceeds max_shards {}",
                    self.min_shards, self.max_shards
                ),
            });
        }
        if self.low_watermark >= self.high_watermark {
            return Err(EngineError::Spec {
                field: "autoscale",
                detail: format!(
                    "low watermark {} must be below the high watermark {}",
                    self.low_watermark, self.high_watermark
                ),
            });
        }
        Ok(())
    }

    fn from_json(v: &Json) -> Result<Self, EngineError> {
        let entries = obj_entries(v, "autoscale")?;
        let mut spec = Self::default();
        for (key, val) in entries {
            match key.as_str() {
                "min_shards" => spec.min_shards = json_usize(val, "autoscale.min_shards")?,
                "max_shards" => spec.max_shards = json_usize(val, "autoscale.max_shards")?,
                "high_watermark" => {
                    spec.high_watermark = json_usize(val, "autoscale.high_watermark")?
                }
                "low_watermark" => {
                    spec.low_watermark = json_usize(val, "autoscale.low_watermark")?
                }
                "cooldown" => spec.cooldown = json_usize(val, "autoscale.cooldown")? as u64,
                "pulse_budget" => {
                    spec.pulse_budget = json_usize(val, "autoscale.pulse_budget")? as u64
                }
                other => {
                    return Err(EngineError::Json(format!(
                        "unknown field 'autoscale.{other}'"
                    )))
                }
            }
        }
        Ok(spec)
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("min_shards".into(), Json::Num(self.min_shards as f64)),
            ("max_shards".into(), Json::Num(self.max_shards as f64)),
            ("high_watermark".into(), Json::Num(self.high_watermark as f64)),
            ("low_watermark".into(), Json::Num(self.low_watermark as f64)),
            ("cooldown".into(), Json::Num(self.cooldown as f64)),
            ("pulse_budget".into(), Json::Num(self.pulse_budget as f64)),
        ])
    }
}

/// Where the served network's weights come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetworkSource {
    /// Trained artifacts when available, template weights otherwise.
    Auto,
    /// The self-contained digit template layer (no artifacts needed).
    Template,
    /// Trained artifacts, required (`make artifacts`).
    Artifact,
    /// N-ary multibit inference (`multibit:BITS[:SCHEME]`): the template
    /// digit network quantized to `bits`-bit weights and lowered onto the
    /// binary substrate the low-power way (Fig. 7(b) unary replication) —
    /// `2^b − 1` columns per logical input. The per-dot-product energy
    /// premium of the chosen scheme ([`multibit_tmvm_cost`]) lands in
    /// [`Telemetry::multibit_energy`](super::api::Telemetry).
    Multibit { bits: usize, scheme: MultibitScheme },
    /// A binary conv bank (`conv:FxKHxKW[:tN]`): `filters` deterministic
    /// Bernoulli(½) `kh×kw` filters over the 11×11 digit image, lowered
    /// to ONE dense layer via the Toeplitz unroll
    /// ([`BinaryConv2d::unrolled_layer`](crate::nn::BinaryConv2d::unrolled_layer))
    /// so tiling, contention and reprogram pricing run unchanged.
    Conv {
        filters: usize,
        kh: usize,
        kw: usize,
        theta: usize,
    },
}

/// Word-line supply at the Table II operating point \[V\] — the voltage
/// every multibit cost estimate and feasibility check prices against.
pub const MULTIBIT_V_DD: f64 = 0.9;

impl NetworkSource {
    /// The source family (the first `:`-token of the spec string).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Template => "template",
            Self::Artifact => "artifact",
            Self::Multibit { .. } => "multibit",
            Self::Conv { .. } => "conv",
        }
    }

    /// Canonical spec string — parses back to `self`
    /// (`parse(spec_str()) == self`), which is what `to_json` writes.
    pub fn spec_str(&self) -> String {
        match self {
            Self::Multibit { bits, scheme } => format!("multibit:{bits}:{}", scheme.name()),
            Self::Conv {
                filters,
                kh,
                kw,
                theta,
            } => format!("conv:{filters}x{kh}x{kw}:t{theta}"),
            other => other.name().to_string(),
        }
    }

    pub fn parse(s: &str) -> Result<Self, EngineError> {
        let lower = s.to_ascii_lowercase();
        let mut parts = lower.split(':');
        let family = parts.next().unwrap_or("");
        let payload: Vec<&str> = parts.collect();
        let bad = |detail: String| EngineError::Spec {
            field: "network",
            detail,
        };
        match family {
            "auto" if payload.is_empty() => Ok(Self::Auto),
            "template" if payload.is_empty() => Ok(Self::Template),
            "artifact" if payload.is_empty() => Ok(Self::Artifact),
            "multibit" => {
                if payload.is_empty() || payload.len() > 2 {
                    return Err(bad(format!(
                        "multibit takes BITS[:SCHEME] (e.g. multibit:2:lowpower), got '{s}'"
                    )));
                }
                let bits = payload[0]
                    .parse::<usize>()
                    .ok()
                    .filter(|b| (1..=8).contains(b))
                    .ok_or_else(|| {
                        bad(format!(
                            "multibit weight resolution must be 1..=8 bits, got '{}'",
                            payload[0]
                        ))
                    })?;
                let scheme = match payload.get(1) {
                    None => MultibitScheme::LowPower,
                    Some(tok) => MultibitScheme::parse(tok).ok_or_else(|| {
                        bad(format!(
                            "unknown multibit scheme '{tok}' (expected lowpower|area)"
                        ))
                    })?,
                };
                Ok(Self::Multibit { bits, scheme })
            }
            "conv" => {
                if payload.is_empty() || payload.len() > 2 {
                    return Err(bad(format!(
                        "conv takes FxKHxKW[:tN] (e.g. conv:4x3x3:t5), got '{s}'"
                    )));
                }
                let dims: Vec<Option<usize>> = payload[0]
                    .split('x')
                    .map(|d| d.parse::<usize>().ok().filter(|&v| v >= 1))
                    .collect();
                let (filters, kh, kw) = match dims.as_slice() {
                    [Some(f), Some(kh), Some(kw)] => (*f, *kh, *kw),
                    _ => {
                        return Err(bad(format!(
                            "conv shape must be FxKHxKW positive integers, got '{}'",
                            payload[0]
                        )))
                    }
                };
                if kh > crate::nn::IMAGE_SIDE || kw > crate::nn::IMAGE_SIDE {
                    return Err(bad(format!(
                        "conv kernel {kh}x{kw} does not fit the {side}x{side} digit image",
                        side = crate::nn::IMAGE_SIDE
                    )));
                }
                let theta = match payload.get(1) {
                    None => (kh * kw).div_ceil(2).max(1),
                    Some(tok) => tok
                        .strip_prefix('t')
                        .and_then(|t| t.parse::<usize>().ok())
                        .ok_or_else(|| {
                            bad(format!("conv threshold must look like t5, got '{tok}'"))
                        })?,
                };
                Ok(Self::Conv {
                    filters,
                    kh,
                    kw,
                    theta,
                })
            }
            _ => Err(EngineError::UnknownNetwork(s.to_string())),
        }
    }

    /// Shape `(n_in, n_out)` of the dense layer this source lowers to on
    /// the substrate — what array autosizing and swap-compatibility
    /// checks reason about. The classic sources all serve the 121→10
    /// digit classifier.
    pub fn dense_shape(&self) -> (usize, usize) {
        use crate::nn::{IMAGE_PIXELS, IMAGE_SIDE, N_CLASSES};
        match self {
            Self::Auto | Self::Template | Self::Artifact => (IMAGE_PIXELS, N_CLASSES),
            Self::Multibit { bits, .. } => {
                let copies = (1usize << bits) - 1;
                (IMAGE_PIXELS * copies, N_CLASSES)
            }
            Self::Conv {
                filters, kh, kw, ..
            } => {
                let (oh, ow) = (IMAGE_SIDE - kh + 1, IMAGE_SIDE - kw + 1);
                (IMAGE_PIXELS, filters * oh * ow)
            }
        }
    }

    /// How many substrate columns each logical input pixel occupies
    /// (the unary replication factor; 1 for everything but multibit).
    /// The serving shell expands every submitted image by this factor.
    pub fn input_expansion(&self) -> usize {
        match self {
            Self::Multibit { bits, .. } => (1usize << bits) - 1,
            _ => 1,
        }
    }

    /// Does this source serve the 10-class digit classifier (so label
    /// accuracy is meaningful)? Conv banks emit feature maps instead.
    pub fn is_classifier(&self) -> bool {
        !matches!(self, Self::Conv { .. })
    }
}

/// Single-subarray design parameters (the `Ideal`/`Parasitic` backends).
#[derive(Clone, Debug, PartialEq)]
pub struct ArraySpec {
    /// Rows (images a batch can store).
    pub rows: usize,
    /// Columns (must hold the layer's inputs and outputs).
    pub cols: usize,
    /// Metal-line configuration id (paper Table I: 1|2|3).
    pub line_config: usize,
    /// Cell length as a multiple of the configuration minimum.
    pub l_scale: f64,
    /// Cell width as a multiple of the configuration minimum.
    pub w_scale: f64,
    /// Engaged column span for the parasitic corner case; `None` defaults
    /// to the served layer's `n_in` (workload-aware, as `serve` always
    /// did).
    pub span: Option<usize>,
}

impl Default for ArraySpec {
    fn default() -> Self {
        Self {
            rows: 64,
            cols: 128,
            line_config: 3,
            l_scale: 3.0,
            w_scale: 1.0,
            span: None,
        }
    }
}

impl ArraySpec {
    fn line(&self) -> Result<LineConfig, EngineError> {
        match self.line_config {
            1 => Ok(LineConfig::config1()),
            2 => Ok(LineConfig::config2()),
            3 => Ok(LineConfig::config3()),
            other => Err(EngineError::UnknownLineConfig(other.to_string())),
        }
    }

    pub fn validate(&self) -> Result<(), EngineError> {
        if self.rows == 0 || self.cols == 0 {
            return Err(EngineError::Spec {
                field: "array",
                detail: format!(
                    "design must be at least 1×1 cells, got {}×{}",
                    self.rows, self.cols
                ),
            });
        }
        if !(self.l_scale.is_finite() && self.l_scale > 0.0)
            || !(self.w_scale.is_finite() && self.w_scale > 0.0)
        {
            return Err(EngineError::Spec {
                field: "array",
                detail: format!(
                    "cell scales must be positive and finite, got l_scale={} w_scale={}",
                    self.l_scale, self.w_scale
                ),
            });
        }
        self.line()?;
        if let Some(span) = self.span {
            if span < 1 || span > self.cols {
                return Err(EngineError::BadSpan {
                    span,
                    n_col: self.cols,
                });
            }
        }
        Ok(())
    }

    /// The [`ArrayDesign`] this spec describes (explicit span applied;
    /// `span: None` is resolved against the served layer at build time).
    pub fn design(&self) -> Result<ArrayDesign, EngineError> {
        self.validate()?;
        let mut d = ArrayDesign::new(
            self.rows,
            self.cols,
            self.line()?,
            self.l_scale,
            self.w_scale,
        );
        if let Some(span) = self.span {
            d = d.with_span(span);
        }
        Ok(d)
    }

    fn from_json(v: &Json) -> Result<Self, EngineError> {
        let entries = obj_entries(v, "array")?;
        let mut spec = Self::default();
        for (key, val) in entries {
            match key.as_str() {
                "rows" => spec.rows = json_usize(val, "array.rows")?,
                "cols" => spec.cols = json_usize(val, "array.cols")?,
                "line_config" => spec.line_config = json_usize(val, "array.line_config")?,
                "l_scale" => spec.l_scale = json_f64(val, "array.l_scale")?,
                "w_scale" => spec.w_scale = json_f64(val, "array.w_scale")?,
                "span" => {
                    spec.span = if val.is_null() {
                        None
                    } else {
                        Some(json_usize(val, "array.span")?)
                    }
                }
                other => return Err(EngineError::Json(format!("unknown field 'array.{other}'"))),
            }
        }
        Ok(spec)
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("rows".into(), Json::Num(self.rows as f64)),
            ("cols".into(), Json::Num(self.cols as f64)),
            ("line_config".into(), Json::Num(self.line_config as f64)),
            ("l_scale".into(), Json::Num(self.l_scale)),
            ("w_scale".into(), Json::Num(self.w_scale)),
            (
                "span".into(),
                match self.span {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Fabric geometry (the `Fabric` backend): subarray grid and tile shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FabricSpec {
    pub grid_rows: usize,
    pub grid_cols: usize,
    /// Rows per subarray tile.
    pub tile_rows: usize,
    /// Columns per subarray tile.
    pub tile_cols: usize,
    /// Images accepted per `infer_batch` call (bounds simulation memory).
    pub max_batch: usize,
    /// How tiles walk the node grid ([`PlacementStrategy`]): flat
    /// round-robin (historical default) or the locality-aware serpentine
    /// that keeps consecutive layers one interlink hop apart.
    pub placement: PlacementStrategy,
}

impl Default for FabricSpec {
    fn default() -> Self {
        Self {
            grid_rows: 2,
            grid_cols: 2,
            tile_rows: 64,
            tile_cols: 32,
            max_batch: 1024,
            placement: PlacementStrategy::RoundRobin,
        }
    }
}

impl FabricSpec {
    pub fn validate(&self) -> Result<(), EngineError> {
        if self.grid_rows == 0 || self.grid_cols == 0 {
            return Err(EngineError::EmptyGrid {
                rows: self.grid_rows,
                cols: self.grid_cols,
            });
        }
        if self.tile_rows == 0 || self.tile_cols == 0 {
            return Err(EngineError::EmptyTile {
                rows: self.tile_rows,
                cols: self.tile_cols,
            });
        }
        if self.max_batch == 0 {
            return Err(EngineError::ZeroBatch);
        }
        Ok(())
    }

    /// The [`FabricConfig`] this spec describes.
    pub fn config(&self) -> FabricConfig {
        FabricConfig::new(
            self.grid_rows,
            self.grid_cols,
            self.tile_rows,
            self.tile_cols,
        )
        .with_strategy(self.placement)
    }

    fn from_json(v: &Json) -> Result<Self, EngineError> {
        let entries = obj_entries(v, "fabric")?;
        let mut spec = Self::default();
        for (key, val) in entries {
            match key.as_str() {
                "grid_rows" => spec.grid_rows = json_usize(val, "fabric.grid_rows")?,
                "grid_cols" => spec.grid_cols = json_usize(val, "fabric.grid_cols")?,
                "tile_rows" => spec.tile_rows = json_usize(val, "fabric.tile_rows")?,
                "tile_cols" => spec.tile_cols = json_usize(val, "fabric.tile_cols")?,
                "max_batch" => spec.max_batch = json_usize(val, "fabric.max_batch")?,
                "placement" => {
                    spec.placement =
                        PlacementStrategy::parse(json_str(val, "fabric.placement")?)?
                }
                other => return Err(EngineError::Json(format!("unknown field 'fabric.{other}'"))),
            }
        }
        Ok(spec)
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("grid_rows".into(), Json::Num(self.grid_rows as f64)),
            ("grid_cols".into(), Json::Num(self.grid_cols as f64)),
            ("tile_rows".into(), Json::Num(self.tile_rows as f64)),
            ("tile_cols".into(), Json::Num(self.tile_cols as f64)),
            ("max_batch".into(), Json::Num(self.max_batch as f64)),
            ("placement".into(), Json::Str(self.placement.name().into())),
        ])
    }
}

/// Coordinator batching policy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Max images per dispatched batch.
    pub capacity: usize,
    /// How long a partial batch may wait before shipping \[µs\].
    pub linger_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            capacity: 64,
            linger_us: 200,
        }
    }
}

impl BatchPolicy {
    fn from_json(v: &Json) -> Result<Self, EngineError> {
        let entries = obj_entries(v, "batching")?;
        let mut spec = Self::default();
        for (key, val) in entries {
            match key.as_str() {
                "capacity" => spec.capacity = json_usize(val, "batching.capacity")?,
                "linger_us" => spec.linger_us = json_usize(val, "batching.linger_us")? as u64,
                other => {
                    return Err(EngineError::Json(format!("unknown field 'batching.{other}'")))
                }
            }
        }
        Ok(spec)
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("capacity".into(), Json::Num(self.capacity as f64)),
            ("linger_us".into(), Json::Num(self.linger_us as f64)),
        ])
    }
}

/// One declarative engine configuration — see the module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineSpec {
    /// Backend fidelity.
    pub kind: BackendKind,
    /// Worker engines the coordinator spawns (one thread each).
    pub workers: usize,
    /// Where the served weights come from (ignored when explicit layers
    /// are attached via [`with_layers`](EngineSpec::with_layers)).
    pub network: NetworkSource,
    /// Reprogramming/swap section: a network to live-swap to mid-serve
    /// (`--swap-to template|artifact|auto`). Resolved by
    /// [`resolve_swap_layers`](EngineSpec::resolve_swap_layers); rejected
    /// for the XLA backend, whose weights are baked into the AOT graph.
    pub swap_to: Option<NetworkSource>,
    /// Single-subarray design (`Ideal`/`Parasitic`).
    pub array: ArraySpec,
    /// Fabric geometry (`Fabric`).
    pub fabric: FabricSpec,
    /// Sharding topology (`Sharded`).
    pub sharding: ShardSpec,
    /// Elastic autoscaling (`Sharded` only): when present, the shard
    /// fleet starts at `min_shards` and the coordinator's scheduler
    /// evaluates the policy live (`--autoscale min,max`).
    pub autoscale: Option<AutoscaleSpec>,
    /// Remote shard hosts (`Remote` and `Sharded`): endpoints that join
    /// the fleet over the wire protocol (`--remote host:port|unix:/path`).
    pub remote: RemoteSpec,
    /// Coordinator batching policy.
    pub batching: BatchPolicy,
    /// Explicit layer stack (code-level override; never serialized).
    layers: Option<Vec<BinaryLayer>>,
}

impl Default for EngineSpec {
    fn default() -> Self {
        Self::new(BackendKind::Ideal)
    }
}

impl EngineSpec {
    pub fn new(kind: BackendKind) -> Self {
        Self {
            kind,
            workers: 2,
            network: NetworkSource::Auto,
            swap_to: None,
            array: ArraySpec::default(),
            fabric: FabricSpec::default(),
            sharding: ShardSpec::default(),
            autoscale: None,
            remote: RemoteSpec::default(),
            batching: BatchPolicy::default(),
            layers: None,
        }
    }

    /// The backend kind that actually serves requests: the inner kind for
    /// `Sharded` specs, `kind` itself otherwise.
    pub fn effective_kind(&self) -> BackendKind {
        if self.kind == BackendKind::Sharded {
            self.sharding.inner
        } else {
            self.kind
        }
    }

    // ------------------------------------------------------------ builder

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn with_network(mut self, network: NetworkSource) -> Self {
        self.network = network;
        self
    }

    /// Attach a reprogramming target: the network the serving shell will
    /// live-swap to mid-run (rolling drain → reprogram → rejoin).
    pub fn with_swap_to(mut self, source: NetworkSource) -> Self {
        self.swap_to = Some(source);
        self
    }

    pub fn with_array(mut self, array: ArraySpec) -> Self {
        self.array = array;
        self
    }

    pub fn with_grid(mut self, rows: usize, cols: usize) -> Self {
        self.fabric.grid_rows = rows;
        self.fabric.grid_cols = cols;
        self
    }

    pub fn with_tile(mut self, rows: usize, cols: usize) -> Self {
        self.fabric.tile_rows = rows;
        self.fabric.tile_cols = cols;
        self
    }

    pub fn with_fabric_max_batch(mut self, max_batch: usize) -> Self {
        self.fabric.max_batch = max_batch;
        self
    }

    /// Wrap the spec in a sharded topology: `shards` independent copies
    /// of the `inner` backend behind the asynchronous scheduler.
    pub fn with_shards(mut self, shards: usize, inner: BackendKind) -> Self {
        self.kind = BackendKind::Sharded;
        self.sharding = ShardSpec {
            shards,
            inner,
            ..self.sharding
        };
        self
    }

    /// Make the sharded fleet elastic: the currently selected backend
    /// becomes the shard template, the engine starts at
    /// `auto.min_shards`, and spawn/retire follow the policy parameters.
    pub fn with_autoscale(mut self, auto: AutoscaleSpec) -> Self {
        let inner = self.effective_kind();
        self.kind = BackendKind::Sharded;
        self.sharding = ShardSpec {
            shards: auto.min_shards.max(1),
            inner,
            ..ShardSpec::default()
        };
        self.autoscale = Some(auto);
        self
    }

    /// Point the spec at remote shard hosts. One address on a
    /// non-sharded spec selects the `Remote` backend outright; on a
    /// `Sharded` spec (or with several addresses) every endpoint joins
    /// the fleet as one extra shard next to the local ones.
    pub fn with_remote<I, S>(mut self, addrs: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.remote.addrs = addrs.into_iter().map(Into::into).collect();
        if self.kind != BackendKind::Sharded {
            if self.remote.addrs.len() == 1 {
                self.kind = BackendKind::Remote;
            } else {
                // several hosts, no local shards: an all-remote fleet
                self.kind = BackendKind::Sharded;
                self.sharding = ShardSpec {
                    shards: 0,
                    inner: BackendKind::Ideal,
                    ..ShardSpec::default()
                };
            }
        }
        self.workers = 1;
        self
    }

    /// Select the fabric's tile [`PlacementStrategy`].
    pub fn with_placement(mut self, placement: PlacementStrategy) -> Self {
        self.fabric.placement = placement;
        self
    }

    pub fn with_batching(mut self, capacity: usize, linger_us: u64) -> Self {
        self.batching = BatchPolicy {
            capacity,
            linger_us,
        };
        self
    }

    /// Attach an explicit layer stack (benches/tests/examples with their
    /// own weights). `Ideal`/`Parasitic` take exactly one layer; `Fabric`
    /// takes the whole stack; `Xla` always loads from artifacts.
    pub fn with_layers(mut self, layers: Vec<BinaryLayer>) -> Self {
        self.layers = Some(layers);
        self
    }

    /// The explicitly attached layer stack, if any.
    pub fn layers(&self) -> Option<&[BinaryLayer]> {
        self.layers.as_deref()
    }

    // --------------------------------------------------------- validation

    pub fn validate(&self) -> Result<(), EngineError> {
        if self.workers == 0 {
            return Err(EngineError::ZeroWorkers);
        }
        if self.batching.capacity == 0 {
            return Err(EngineError::ZeroBatch);
        }
        if let Some(auto) = &self.autoscale {
            if self.kind != BackendKind::Sharded {
                return Err(EngineError::Spec {
                    field: "autoscale",
                    detail: format!(
                        "autoscaling scales shards — it needs the sharded backend, \
                         not {}",
                        self.kind.name()
                    ),
                });
            }
            auto.validate()?;
            // the elastic fleet starts at min_shards; a disagreeing fixed
            // shard count would be silently ignored — reject it instead
            if self.sharding.shards != auto.min_shards {
                return Err(EngineError::Spec {
                    field: "autoscale",
                    detail: format!(
                        "the elastic fleet starts at autoscale.min_shards ({}) but \
                         sharding.shards is {} — set them equal (or drop the \
                         sharding count and let autoscale govern it)",
                        auto.min_shards, self.sharding.shards
                    ),
                });
            }
        }
        if self.kind == BackendKind::Sharded {
            // a fleet of zero local shards is fine when remote hosts fill it
            if self.sharding.shards == 0 && self.remote.addrs.is_empty() {
                return Err(EngineError::ZeroShards);
            }
            match self.sharding.inner {
                BackendKind::Sharded => {
                    return Err(EngineError::Spec {
                        field: "sharding",
                        detail: "shards cannot nest — the inner backend must be \
                                 ideal|parasitic|fabric"
                            .into(),
                    });
                }
                BackendKind::Xla => {
                    return Err(EngineError::Spec {
                        field: "sharding",
                        detail: "the xla backend cannot be sharded — PJRT clients are \
                                 thread-affine; scale it with --workers instead"
                            .into(),
                    });
                }
                BackendKind::Remote => {
                    return Err(EngineError::Spec {
                        field: "sharding",
                        detail: "remote shards join the fleet through the remote.addrs \
                                 section (--remote), not as the inner backend"
                            .into(),
                    });
                }
                _ => {}
            }
        }
        if self.sharding.canary != 0.0 {
            if self.kind != BackendKind::Sharded {
                return Err(EngineError::Spec {
                    field: "sharding",
                    detail: "a canary shard rides a sharded fleet — select the \
                             sharded backend (--shards N --canary F)"
                        .into(),
                });
            }
            if !(self.sharding.canary > 0.0 && self.sharding.canary <= 1.0) {
                return Err(EngineError::Spec {
                    field: "sharding",
                    detail: format!(
                        "canary sampling fraction must be in (0, 1], got {}",
                        self.sharding.canary
                    ),
                });
            }
            if self.autoscale.is_some() {
                return Err(EngineError::Spec {
                    field: "sharding",
                    detail: "canary and autoscale are mutually exclusive — the \
                             canary is a pinned slot the elastic walk would \
                             retire or clone"
                        .into(),
                });
            }
            if self.sharding.inner != BackendKind::Ideal {
                return Err(EngineError::Spec {
                    field: "sharding",
                    detail: format!(
                        "the canary shadows ideal primaries with its parasitic \
                         fidelity — sharding.inner must be ideal, not {}",
                        self.sharding.inner.name()
                    ),
                });
            }
        }
        if !self.remote.addrs.is_empty() || self.kind == BackendKind::Remote {
            self.remote.validate()?;
            match self.kind {
                BackendKind::Remote => {
                    if self.remote.addrs.len() != 1 {
                        return Err(EngineError::Spec {
                            field: "remote",
                            detail: format!(
                                "the remote backend drives exactly one shard host, got \
                                 {} addresses (shard a fleet with --shards/--autoscale)",
                                self.remote.addrs.len()
                            ),
                        });
                    }
                    if self.layers.is_some() {
                        return Err(EngineError::Spec {
                            field: "layers",
                            detail: "a remote shard serves the network resident on its \
                                     host — explicit layers have nowhere to go"
                                .into(),
                        });
                    }
                }
                BackendKind::Sharded => {}
                other => {
                    return Err(EngineError::Spec {
                        field: "remote",
                        detail: format!(
                            "remote shard addresses need the remote or sharded \
                             backend, not {}",
                            other.name()
                        ),
                    });
                }
            }
            // a shard host serves one connection at a time, so a second
            // coordinator worker would block in connect() forever
            if self.workers != 1 {
                return Err(EngineError::Spec {
                    field: "workers",
                    detail: format!(
                        "a shard host serves one connection at a time — remote \
                         fleets take exactly 1 coordinator worker, got {}",
                        self.workers
                    ),
                });
            }
        }
        match self.effective_kind() {
            BackendKind::Ideal | BackendKind::Parasitic => self.array.validate()?,
            BackendKind::Fabric => self.fabric.validate()?,
            BackendKind::Xla => {
                // the XLA graph's weights are baked in at AOT-compile time;
                // it can neither serve template weights nor swap in place
                if self.swap_to.is_some() {
                    return Err(EngineError::Spec {
                        field: "swap_to",
                        detail: "the xla backend cannot reprogram weights in place — \
                                 its network is baked into the AOT graph"
                            .into(),
                    });
                }
                if !matches!(
                    self.network,
                    NetworkSource::Artifact | NetworkSource::Auto
                ) {
                    return Err(EngineError::Spec {
                        field: "network",
                        detail: "the xla backend always loads its network from \
                                 artifacts (use network source 'artifact' or 'auto')"
                            .into(),
                    });
                }
            }
            // the host validates its own spec; nothing local to check
            BackendKind::Remote => {}
            // unreachable: nesting was rejected above
            BackendKind::Sharded => {}
        }
        // every backend has a hard per-call batch limit; a coordinator
        // capacity above it would fail (or panic) per batch on the worker
        // thread, so reject the mismatch here instead (a sharded engine's
        // limit is its inner backend's — each batch lands on one shard)
        let backend_max = match self.effective_kind() {
            BackendKind::Ideal | BackendKind::Parasitic => self.array.rows,
            BackendKind::Fabric => self.fabric.max_batch,
            BackendKind::Xla => XLA_GRAPH_BATCH,
            // the host enforces its own limit per call, with a typed error
            BackendKind::Remote => usize::MAX,
            BackendKind::Sharded => usize::MAX, // unreachable after the nest check
        };
        if self.batching.capacity > backend_max {
            return Err(EngineError::Spec {
                field: "batching",
                detail: format!(
                    "batch capacity {} exceeds the {} backend's max batch {}",
                    self.batching.capacity,
                    self.kind.name(),
                    backend_max
                ),
            });
        }
        // multibit feasibility: the area-efficient scheme's top word-line
        // voltage (V_DD·2^(b−1)) breaches the subarray ceiling past 3 bits
        // at the Table II operating point — reject instead of serving a
        // physically impossible configuration (paper §VI-B)
        for source in std::iter::once(&self.network).chain(self.swap_to.iter()) {
            if let NetworkSource::Multibit { bits, scheme } = source {
                let max_voltage = match scheme {
                    MultibitScheme::AreaEfficient => {
                        MULTIBIT_V_DD * (1u64 << (bits - 1)) as f64
                    }
                    MultibitScheme::LowPower => MULTIBIT_V_DD,
                };
                if max_voltage > V_CEILING {
                    return Err(EngineError::Spec {
                        field: "network",
                        detail: format!(
                            "multibit scheme '{}' at {bits} bits needs a {max_voltage:.1} V \
                             word line — over the {V_CEILING:.0} V subarray ceiling \
                             (use the lowpower scheme or at most 3 bits)",
                            scheme.name()
                        ),
                    });
                }
            }
        }
        // a live swap reprograms cells in place, so both endpoints must
        // lower to the same dense geometry (multibit changes the input
        // expansion; conv changes the output plane)
        if self.layers.is_none() {
            if let Some(target) = &self.swap_to {
                if target.dense_shape() != self.network.dense_shape() {
                    let (ni, no) = self.network.dense_shape();
                    let (ti, to) = target.dense_shape();
                    return Err(EngineError::Spec {
                        field: "swap_to",
                        detail: format!(
                            "cannot live-swap between networks of different substrate \
                             geometry: '{}' lowers to {ni}→{no} but '{}' lowers to \
                             {ti}→{to}",
                            self.network.spec_str(),
                            target.spec_str()
                        ),
                    });
                }
            }
        }
        if let Some(layers) = &self.layers {
            if layers.is_empty() {
                return Err(EngineError::Spec {
                    field: "layers",
                    detail: "explicit layer stack is empty".into(),
                });
            }
            if self.effective_kind() == BackendKind::Xla {
                return Err(EngineError::Spec {
                    field: "layers",
                    detail: "the xla backend loads its network from artifacts".into(),
                });
            }
            if matches!(
                self.effective_kind(),
                BackendKind::Ideal | BackendKind::Parasitic
            ) && layers.len() != 1
            {
                return Err(EngineError::Spec {
                    field: "layers",
                    detail: format!(
                        "the {} backend serves exactly one layer, got {}",
                        self.effective_kind().name(),
                        layers.len()
                    ),
                });
            }
            for (i, l) in layers.iter().enumerate() {
                if l.n_out() == 0 || l.n_in() == 0 {
                    return Err(EngineError::EmptyLayer {
                        index: i,
                        n_out: l.n_out(),
                        n_in: l.n_in(),
                    });
                }
            }
        }
        Ok(())
    }

    // ---------------------------------------------------------- CLI flags

    /// Build a spec from `xpoint serve` flags: an optional `--engine
    /// path.json` base overlaid with `--xla`/`--fabric`/`--parasitic`,
    /// `--shards N`, `--remote host:port|unix:/path[,..]`, `--grid N`,
    /// `--placement S`, `--batch N` and `--workers N`. Conflicting flag
    /// combinations are rejected with one typed error each.
    pub fn from_args(args: &Args) -> Result<Self, EngineError> {
        let json_base = args.get("engine").is_some();
        let mut spec = match args.get("engine") {
            Some(path) => Self::from_json_file(Path::new(path))?,
            None => Self::default(),
        };
        spec.apply_args(args, json_base)?;
        spec.validate()?;
        Ok(spec)
    }

    fn apply_args(&mut self, args: &Args, json_base: bool) -> Result<(), EngineError> {
        let xla = args.has_flag("xla");
        let fabric = args.has_flag("fabric");
        let parasitic = args.has_flag("parasitic");
        if xla && fabric {
            return Err(EngineError::Conflict {
                first: "--xla",
                second: "--fabric",
            });
        }
        if xla && parasitic {
            return Err(EngineError::Conflict {
                first: "--xla",
                second: "--parasitic",
            });
        }
        if fabric && parasitic {
            return Err(EngineError::Conflict {
                first: "--fabric",
                second: "--parasitic",
            });
        }
        if xla {
            self.kind = BackendKind::Xla;
            self.network = NetworkSource::Artifact;
        } else if fabric {
            self.kind = BackendKind::Fabric;
        } else if parasitic {
            self.kind = BackendKind::Parasitic;
        }
        if let Some(s) = parse_opt_usize(args, "shards")? {
            if xla {
                return Err(EngineError::Conflict {
                    first: "--shards",
                    second: "--xla",
                });
            }
            if s == 0 {
                return Err(EngineError::ZeroShards);
            }
            // wrap whatever backend the other flags (or the spec file)
            // selected; effective_kind() keeps an already-sharded JSON
            // base from nesting
            self.sharding = ShardSpec {
                shards: s,
                inner: self.effective_kind(),
                ..self.sharding
            };
            self.kind = BackendKind::Sharded;
            // the shards already parallelize across their own threads, so
            // one coordinator worker drives them unless --workers (or an
            // explicit spec file) says otherwise
            if !json_base && args.get("workers").is_none() {
                self.workers = 1;
            }
        }
        if let Some(w) = parse_opt_usize(args, "workers")? {
            self.workers = w;
        }
        if let Some(b) = parse_opt_usize(args, "batch")? {
            if json_base {
                // an explicit --engine spec owns the array design — --batch
                // only adjusts the coordinator batch (still capped to the
                // fixed XLA graph shape when that backend serves it)
                self.batching.capacity = if self.kind == BackendKind::Xla {
                    b.min(XLA_GRAPH_BATCH)
                } else {
                    b
                };
            } else {
                // the historical `--batch` contract: the coordinator batch
                // is capped at the XLA graph shape and the subarray is
                // sized to store the whole batch
                self.batching.capacity = b.min(XLA_GRAPH_BATCH);
                self.array.rows = b.max(XLA_GRAPH_BATCH);
            }
        }
        if let Some(bounds) = args.get("autoscale") {
            if xla {
                return Err(EngineError::Conflict {
                    first: "--autoscale",
                    second: "--xla",
                });
            }
            if args.get("shards").is_some() {
                return Err(EngineError::Conflict {
                    first: "--autoscale",
                    second: "--shards",
                });
            }
            let (min, max) = parse_autoscale_bounds(bounds)?;
            // watermarks track the (final) coordinator batch capacity
            let auto = AutoscaleSpec::for_batch(min, max, self.batching.capacity);
            let inner = self.effective_kind();
            self.sharding = ShardSpec {
                shards: min.max(1),
                inner,
                ..ShardSpec::default()
            };
            self.kind = BackendKind::Sharded;
            self.autoscale = Some(auto);
            // like --shards: the elastic fleet parallelizes on its own
            // threads, so one coordinator worker drives it by default
            if !json_base && args.get("workers").is_none() {
                self.workers = 1;
            }
        }
        if let Some(f) = args.get("canary") {
            if args.get("autoscale").is_some() {
                return Err(EngineError::Conflict {
                    first: "--canary",
                    second: "--autoscale",
                });
            }
            // a canary rides an explicit sharded fleet (--shards N, or a
            // sharded --engine spec file)
            if self.kind != BackendKind::Sharded {
                return Err(EngineError::Requires {
                    option: "--canary",
                    requires: "--shards",
                });
            }
            self.sharding.canary = f.trim().parse().map_err(|_| EngineError::Spec {
                field: "sharding",
                detail: format!(
                    "--canary expects a sampling fraction in (0, 1], got '{f}'"
                ),
            })?;
        }
        if let Some(addrs) = args.get_list("remote") {
            if xla {
                return Err(EngineError::Conflict {
                    first: "--remote",
                    second: "--xla",
                });
            }
            if addrs.is_empty() {
                return Err(EngineError::Spec {
                    field: "remote",
                    detail: "--remote expects host:port or unix:/path endpoints \
                             (comma-separated)"
                        .into(),
                });
            }
            if self.kind != BackendKind::Sharded {
                // without local shards the fidelity flags describe local
                // fabric this spec doesn't have — the host owns its model
                if fabric {
                    return Err(EngineError::Conflict {
                        first: "--remote",
                        second: "--fabric",
                    });
                }
                if parasitic {
                    return Err(EngineError::Conflict {
                        first: "--remote",
                        second: "--parasitic",
                    });
                }
                if addrs.len() == 1 {
                    self.kind = BackendKind::Remote;
                } else {
                    // several hosts, no local shards: an all-remote fleet
                    self.kind = BackendKind::Sharded;
                    self.sharding = ShardSpec {
                        shards: 0,
                        inner: BackendKind::Ideal,
                        ..ShardSpec::default()
                    };
                }
            }
            self.remote.addrs = addrs;
            // a shard host serves one connection at a time, so the fleet
            // takes one coordinator worker (validate() rejects more)
            if !json_base && args.get("workers").is_none() {
                self.workers = 1;
            }
        }
        if let Some(g) = parse_opt_usize(args, "grid")? {
            if self.effective_kind() != BackendKind::Fabric {
                return Err(EngineError::Requires {
                    option: "--grid",
                    requires: "--fabric",
                });
            }
            if g == 0 {
                return Err(EngineError::EmptyGrid { rows: g, cols: g });
            }
            self.fabric.grid_rows = g;
            self.fabric.grid_cols = g;
        }
        if let Some(p) = args.get("placement") {
            if self.effective_kind() != BackendKind::Fabric {
                return Err(EngineError::Requires {
                    option: "--placement",
                    requires: "--fabric",
                });
            }
            self.fabric.placement = PlacementStrategy::parse(p)?;
        }
        if let Some(s) = args.get("network") {
            if xla {
                // --xla pins the network to its AOT-compiled artifacts
                return Err(EngineError::Conflict {
                    first: "--network",
                    second: "--xla",
                });
            }
            self.network = NetworkSource::parse(s)?;
        }
        if let Some(s) = args.get("swap-to") {
            if xla {
                return Err(EngineError::Conflict {
                    first: "--swap-to",
                    second: "--xla",
                });
            }
            self.swap_to = Some(NetworkSource::parse(s)?);
        }
        // CLI-path array autosizing: multibit/conv lower to layers wider
        // than the 128-column default subarray, so grow the design to fit
        // the workload (an explicit --engine spec owns its array and gets
        // the typed LayerTooLarge at build time instead)
        if !json_base {
            for source in std::iter::once(&self.network).chain(self.swap_to.iter()) {
                let (n_in, n_out) = source.dense_shape();
                self.array.cols = self.array.cols.max(n_in).max(n_out);
            }
        }
        Ok(())
    }

    // --------------------------------------------------------------- JSON

    /// Serialize to the JSON spec format (inverse of
    /// [`from_json`](EngineSpec::from_json); explicit layers are not
    /// serialized).
    pub fn to_json(&self) -> String {
        let obj = Json::Obj(vec![
            ("backend".into(), Json::Str(self.kind.name().into())),
            ("workers".into(), Json::Num(self.workers as f64)),
            ("network".into(), Json::Str(self.network.spec_str())),
            (
                "swap_to".into(),
                match &self.swap_to {
                    Some(s) => Json::Str(s.spec_str()),
                    None => Json::Null,
                },
            ),
            ("array".into(), self.array.to_json()),
            ("fabric".into(), self.fabric.to_json()),
            ("sharding".into(), self.sharding.to_json()),
            (
                "autoscale".into(),
                match &self.autoscale {
                    Some(a) => a.to_json(),
                    None => Json::Null,
                },
            ),
            ("remote".into(), self.remote.to_json()),
            ("batching".into(), self.batching.to_json()),
        ]);
        let mut s = obj.pretty();
        s.push('\n');
        s
    }

    /// Parse and validate a JSON spec. Missing fields take their
    /// defaults; unknown fields are rejected (typo protection).
    pub fn from_json(text: &str) -> Result<Self, EngineError> {
        let v = Json::parse(text).map_err(EngineError::Json)?;
        let entries = obj_entries(&v, "engine spec")?;
        let mut spec = Self::default();
        let mut saw_sharding = false;
        for (key, val) in entries {
            match key.as_str() {
                "backend" => spec.kind = BackendKind::parse(json_str(val, "backend")?)?,
                "workers" => spec.workers = json_usize(val, "workers")?,
                "network" => spec.network = NetworkSource::parse(json_str(val, "network")?)?,
                "swap_to" => {
                    spec.swap_to = if val.is_null() {
                        None
                    } else {
                        Some(NetworkSource::parse(json_str(val, "swap_to")?)?)
                    }
                }
                "array" => spec.array = ArraySpec::from_json(val)?,
                "fabric" => spec.fabric = FabricSpec::from_json(val)?,
                "sharding" => {
                    spec.sharding = ShardSpec::from_json(val)?;
                    saw_sharding = true;
                }
                "autoscale" => {
                    spec.autoscale = if val.is_null() {
                        None
                    } else {
                        Some(AutoscaleSpec::from_json(val)?)
                    }
                }
                "remote" => spec.remote = RemoteSpec::from_json(val)?,
                "batching" => spec.batching = BatchPolicy::from_json(val)?,
                other => return Err(EngineError::Json(format!("unknown field '{other}'"))),
            }
        }
        // a spec that only gives the autoscale section lets it govern the
        // fleet size; an *explicit* disagreeing sharding count is rejected
        // by validate() below
        if let Some(auto) = &spec.autoscale {
            if !saw_sharding {
                spec.sharding.shards = auto.min_shards;
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load a JSON spec from disk (`--engine path.json`).
    pub fn from_json_file(path: &Path) -> Result<Self, EngineError> {
        let text = crate::util::io::read_text(path)
            .map_err(|e| EngineError::Json(format!("{e:#}")))?;
        Self::from_json(&text).map_err(|e| match e {
            EngineError::Json(detail) => {
                EngineError::Json(format!("{}: {detail}", path.display()))
            }
            other => other,
        })
    }

    // ------------------------------------------------------------ serving

    /// One-line human description of the configured backend.
    pub fn describe(&self) -> String {
        match self.kind {
            BackendKind::Xla => "XLA golden model (PJRT CPU, one client per worker)".to_string(),
            BackendKind::Fabric => format!(
                "event-driven fabric simulator ({}×{} subarray grid per worker, {} placement)",
                self.fabric.grid_rows,
                self.fabric.grid_cols,
                self.fabric.placement.name()
            ),
            BackendKind::Ideal => "circuit-level simulator (Ideal)".to_string(),
            BackendKind::Parasitic => "circuit-level simulator (Parasitic)".to_string(),
            BackendKind::Remote => format!(
                "remote shard host at {}",
                self.remote.addrs.first().map(String::as_str).unwrap_or("<unset>")
            ),
            BackendKind::Sharded => {
                let mut inner = self.clone();
                inner.kind = self.sharding.inner;
                inner.autoscale = None;
                inner.remote = RemoteSpec::default();
                let remote = match self.remote.addrs.len() {
                    0 => String::new(),
                    n => format!(" + {n} remote host(s)"),
                };
                match &self.autoscale {
                    Some(a) => format!(
                        "elastic sharded engine: {}..={} shard(s) (queue-driven \
                         autoscale), each a {}{remote}",
                        a.min_shards,
                        a.max_shards,
                        inner.describe()
                    ),
                    None => {
                        let canary = if self.sharding.canary > 0.0 {
                            format!(
                                " + parasitic canary sampling {:.0}% of traffic",
                                self.sharding.canary * 100.0
                            )
                        } else {
                            String::new()
                        };
                        format!(
                            "async sharded engine: {} shard(s), each a {}{remote}{canary}",
                            self.sharding.shards,
                            inner.describe()
                        )
                    }
                }
            }
        }
    }

    /// The coordinator configuration this spec's batching and autoscale
    /// policies imply.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            batch_capacity: self.batching.capacity,
            linger: Duration::from_micros(self.batching.linger_us),
            autoscale: self.autoscale.as_ref().map(AutoscalePolicy::from_spec),
        }
    }

    // ----------------------------------------------------------- registry

    /// Resolve a [`NetworkSource`] to its layer stack.
    fn layers_from_source(source: &NetworkSource) -> Result<Vec<BinaryLayer>, EngineError> {
        fn from_store(store: &ArtifactStore) -> Result<Vec<BinaryLayer>, EngineError> {
            store
                .single_layer()
                .map(|l| vec![l])
                .map_err(|e| EngineError::Artifacts(format!("loading trained layer: {e:#}")))
        }
        match source {
            NetworkSource::Template => Ok(vec![crate::report::table2::template_layer()]),
            NetworkSource::Artifact => {
                let store = ArtifactStore::open_default().map_err(|_| {
                    EngineError::Artifacts(
                        "network source 'artifact' needs artifacts — run `make artifacts`"
                            .into(),
                    )
                })?;
                from_store(&store)
            }
            NetworkSource::Auto => match ArtifactStore::open_default() {
                Ok(store) => from_store(&store),
                Err(_) => Ok(vec![crate::report::table2::template_layer()]),
            },
            // full-scale quantization of the template classifier, lowered
            // onto the binary substrate by unary replication — bit-exact
            // against the scalar N-ary oracle (see nn::multibit tests)
            NetworkSource::Multibit { bits, .. } => {
                let template = crate::report::table2::template_layer();
                let multibit = crate::nn::MultibitLayer::from_binary(&template, *bits);
                Ok(vec![multibit.lower_unary()])
            }
            // one dense Toeplitz layer over the flat digit image —
            // bit-exact against BinaryConv2d::forward_direct
            NetworkSource::Conv {
                filters,
                kh,
                kw,
                theta,
            } => {
                let bank = crate::nn::conv_bank(*filters, *kh, *kw, *theta);
                let layer = bank
                    .unrolled_layer(crate::nn::IMAGE_SIDE, crate::nn::IMAGE_SIDE)
                    .map_err(|e| EngineError::Spec {
                        field: "network",
                        detail: e.to_string(),
                    })?;
                Ok(vec![layer])
            }
        }
    }

    /// Resolve the layer stack this spec serves (explicit layers win,
    /// then the configured [`NetworkSource`]).
    fn resolve_layers(&self) -> Result<Vec<BinaryLayer>, EngineError> {
        if let Some(layers) = &self.layers {
            return Ok(layers.clone());
        }
        Self::layers_from_source(&self.network)
    }

    /// Resolve the reprogramming target (`swap_to`), if one is
    /// configured — the network the serving shell hands to
    /// [`Engine::swap_network`] mid-run.
    pub fn resolve_swap_layers(&self) -> Result<Option<Vec<BinaryLayer>>, EngineError> {
        match &self.swap_to {
            None => Ok(None),
            Some(source) => Self::layers_from_source(source).map(Some),
        }
    }

    /// The Table III cost estimate of this spec's multibit workload, or
    /// `None` when the served network isn't multibit. Priced per logical
    /// dot product (`n_inputs` = the digit image's 121 pixels) at the
    /// Table II operating point.
    pub fn multibit_cost(&self) -> Option<MultibitCost> {
        match &self.network {
            NetworkSource::Multibit { bits, scheme } => {
                let design = self.array.design().ok()?;
                Some(multibit_tmvm_cost(
                    &design,
                    *scheme,
                    *bits,
                    crate::nn::IMAGE_PIXELS,
                    MULTIBIT_V_DD,
                ))
            }
            _ => None,
        }
    }

    /// Energy premium one served image adds on a multibit workload
    /// (`N_CLASSES` logical dot products priced by
    /// [`multibit_cost`](Self::multibit_cost)); 0 otherwise. Backends add
    /// `n_images × premium` into [`Telemetry::multibit_energy`]
    /// (and total energy) per inference call.
    ///
    /// [`Telemetry::multibit_energy`]: super::api::Telemetry::multibit_energy
    pub fn multibit_premium(&self) -> f64 {
        self.multibit_cost()
            .map(|c| c.energy * crate::nn::N_CLASSES as f64)
            .unwrap_or(0.0)
    }

    /// The registry: turn the spec into a [`BackendFactory`] for its
    /// backend kind. Validation (shapes, placement, artifacts) happens
    /// here, eagerly, on the calling thread — a bad spec fails the build
    /// with a typed error instead of killing a worker thread later.
    pub fn build(&self) -> Result<BackendFactory, EngineError> {
        Ok(self.build_many(1)?.pop().expect("one factory"))
    }

    /// One factory per configured worker.
    pub fn build_factories(&self) -> Result<Vec<BackendFactory>, EngineError> {
        self.build_many(self.workers)
    }

    /// Shared resolution — layer loading, artifact reads, eager placement
    /// and shape checks — runs **once** per spec here; only cheap clones
    /// go into the `n` per-worker factories.
    fn build_many(&self, n: usize) -> Result<Vec<BackendFactory>, EngineError> {
        self.validate()?;
        match self.kind {
            BackendKind::Ideal | BackendKind::Parasitic => {
                let mode = match self.kind {
                    BackendKind::Ideal => TmvmMode::Ideal,
                    _ => TmvmMode::Parasitic,
                };
                // validate() rejected explicit multi-layer stacks and every
                // network source resolves to exactly one layer
                let mut layers = self.resolve_layers()?;
                debug_assert_eq!(layers.len(), 1, "sim backends serve one layer");
                let layer = layers.pop().expect("resolved non-empty");
                let mut design = self.array.design()?;
                SimBackend::validate_shapes(&layer, &design)?;
                if self.array.span.is_none() {
                    // workload-aware engaged span (what `serve` always used)
                    design = design.with_span(layer.n_in().clamp(1, design.n_col));
                }
                let premium = self.multibit_premium();
                Ok((0..n)
                    .map(|_| {
                        let layer = layer.clone();
                        let design = design.clone();
                        Box::new(move || {
                            Ok(Box::new(
                                SimBackend::new(layer, design, mode)?
                                    .with_multibit_premium(premium),
                            ) as Box<dyn Engine>)
                        }) as BackendFactory
                    })
                    .collect())
            }
            BackendKind::Fabric => {
                let layers = self.resolve_layers()?;
                let cfg = self.fabric.config();
                // surface placement errors now, on the calling thread
                place_layers(&layers, &cfg)
                    .map_err(|e| EngineError::Placement(format!("{e:#}")))?;
                let max_batch = self.fabric.max_batch;
                let premium = self.multibit_premium();
                Ok((0..n)
                    .map(|_| {
                        let layers = layers.clone();
                        let cfg = cfg.clone();
                        Box::new(move || {
                            Ok(Box::new(
                                FabricBackend::new(layers, cfg, max_batch)?
                                    .with_multibit_premium(premium),
                            ) as Box<dyn Engine>)
                        }) as BackendFactory
                    })
                    .collect())
            }
            BackendKind::Remote => {
                // validate() pinned this to exactly one address and one
                // worker — the host serves a single connection at a time
                let addr = RemoteAddr::parse(&self.remote.addrs[0])?;
                Ok((0..n)
                    .map(|_| {
                        remote_factory(
                            addr.clone(),
                            self.remote.connect_timeout(),
                            self.remote.io_timeout(),
                        )
                    })
                    .collect())
            }
            BackendKind::Sharded => {
                if let Some(auto) = &self.autoscale {
                    // elastic fleet: every coordinator worker owns an
                    // independent elastic engine that starts at
                    // min_shards and spawns/retires from the template;
                    // remote hosts join the initial pool as extra slots
                    let mut inner = self.clone();
                    inner.kind = self.sharding.inner;
                    inner.autoscale = None;
                    inner.remote = RemoteSpec::default();
                    let layers = inner.resolve_layers()?;
                    let builder = self.build_shard_builder(&layers)?;
                    let initial = auto.min_shards;
                    let budget = auto.pulse_budget;
                    let mut out: Vec<BackendFactory> = Vec::with_capacity(n);
                    for _ in 0..n {
                        let builder = builder.clone();
                        let layers = layers.clone();
                        let extras = self.remote_factories()?;
                        out.push(Box::new(move || {
                            Ok(Box::new(ShardedEngine::elastic_with(
                                builder, layers, initial, budget, extras,
                            )?) as Box<dyn Engine>)
                        }) as BackendFactory);
                    }
                    return Ok(out);
                }
                // resolve the inner spec once for all n·shards engines
                // (keeping the once-per-spec contract above), then chunk
                // the factories so every coordinator worker owns an
                // independent sharded engine of `shards` local shards
                // plus one shard per remote host (and, when configured,
                // one parasitic canary slot appended last)
                let mut inner = self.clone();
                inner.kind = self.sharding.inner;
                inner.sharding.canary = 0.0;
                inner.remote = RemoteSpec::default();
                let shards = self.sharding.shards;
                let mut inner_factories = if shards == 0 {
                    Vec::new()
                } else {
                    inner.build_many(n * shards)?
                };
                let fraction = self.sharding.canary;
                let mut out: Vec<BackendFactory> = Vec::with_capacity(n);
                for _ in 0..n {
                    let mut group: Vec<BackendFactory> =
                        inner_factories.drain(..shards).collect();
                    group.extend(self.remote_factories()?);
                    if fraction > 0.0 {
                        group.push(self.canary_factory()?);
                    }
                    out.push(Box::new(move || {
                        Ok(Box::new(if fraction > 0.0 {
                            ShardedEngine::with_canary(group, fraction)?
                        } else {
                            ShardedEngine::new(group)?
                        }) as Box<dyn Engine>)
                    }) as BackendFactory);
                }
                Ok(out)
            }
            BackendKind::Xla => {
                let store = ArtifactStore::open_default().map_err(|_| {
                    EngineError::Artifacts("--xla needs artifacts — run `make artifacts`".into())
                })?;
                let layer = store.single_layer().map_err(|e| {
                    EngineError::Artifacts(format!("loading trained layer: {e:#}"))
                })?;
                let v_dd = store
                    .meta_f64("vdd_single")
                    .map_err(|e| EngineError::Artifacts(format!("vdd_single: {e:#}")))?;
                let hlo = store.nn_infer_hlo();
                Ok((0..n)
                    .map(|_| {
                        let layer = layer.clone();
                        let hlo = hlo.clone();
                        Box::new(move || {
                            let runtime = Runtime::cpu()?;
                            Ok(Box::new(XlaBackend::new(
                                &runtime,
                                &hlo,
                                layer,
                                XLA_GRAPH_BATCH,
                                v_dd,
                            )?) as Box<dyn Engine>)
                        }) as BackendFactory
                    })
                    .collect())
            }
        }
    }

    /// The canary slot's factory: the same array design and network as
    /// the ideal primaries, served at parasitic fidelity (so mirrored
    /// samples walk the corner-circuit model the primaries idealize
    /// away).
    fn canary_factory(&self) -> Result<BackendFactory, EngineError> {
        let mut c = self.clone();
        c.kind = BackendKind::Parasitic;
        c.sharding = ShardSpec::default();
        c.autoscale = None;
        c.remote = RemoteSpec::default();
        Ok(c.build_many(1)?.pop().expect("one factory"))
    }

    /// One [`BackendFactory`] per configured remote shard host — each
    /// connects lazily on its worker/shard thread, exactly like a local
    /// engine builds there. Addresses were validated by
    /// [`validate`](EngineSpec::validate); re-parsing here keeps the
    /// helper usable on its own.
    fn remote_factories(&self) -> Result<Vec<BackendFactory>, EngineError> {
        self.remote
            .addrs
            .iter()
            .map(|a| {
                let addr = RemoteAddr::parse(a)?;
                Ok(remote_factory(
                    addr,
                    self.remote.connect_timeout(),
                    self.remote.io_timeout(),
                ))
            })
            .collect()
    }

    /// The reusable elastic shard template this spec describes: builds
    /// one inner engine for a given layer stack (the autoscaler programs
    /// spawned slots to whatever network is resident at spawn time).
    /// Eager validation — placement and shape errors surface here, on
    /// the calling thread, exactly like [`build`](EngineSpec::build).
    fn build_shard_builder(&self, initial: &[BinaryLayer]) -> Result<ShardBuilder, EngineError> {
        match self.sharding.inner {
            BackendKind::Ideal | BackendKind::Parasitic => {
                let mode = match self.sharding.inner {
                    BackendKind::Ideal => TmvmMode::Ideal,
                    _ => TmvmMode::Parasitic,
                };
                if initial.len() != 1 {
                    return Err(EngineError::Spec {
                        field: "layers",
                        detail: format!(
                            "the {} backend serves exactly one layer, got {}",
                            self.sharding.inner.name(),
                            initial.len()
                        ),
                    });
                }
                let layer = &initial[0];
                let mut design = self.array.design()?;
                SimBackend::validate_shapes(layer, &design)?;
                if self.array.span.is_none() {
                    design = design.with_span(layer.n_in().clamp(1, design.n_col));
                }
                let premium = self.multibit_premium();
                let builder: ShardBuilder =
                    std::sync::Arc::new(move |layers: Vec<BinaryLayer>| {
                        anyhow::ensure!(layers.len() == 1, "sim shards serve one layer");
                        let layer = layers.into_iter().next().expect("one layer");
                        Ok(Box::new(
                            SimBackend::new(layer, design.clone(), mode)?
                                .with_multibit_premium(premium),
                        ) as Box<dyn Engine>)
                    });
                Ok(builder)
            }
            BackendKind::Fabric => {
                let cfg = self.fabric.config();
                place_layers(initial, &cfg)
                    .map_err(|e| EngineError::Placement(format!("{e:#}")))?;
                let max_batch = self.fabric.max_batch;
                let premium = self.multibit_premium();
                let builder: ShardBuilder =
                    std::sync::Arc::new(move |layers: Vec<BinaryLayer>| {
                        Ok(Box::new(
                            FabricBackend::new(layers, cfg.clone(), max_batch)?
                                .with_multibit_premium(premium),
                        ) as Box<dyn Engine>)
                    });
                Ok(builder)
            }
            // validate() rejected these inner kinds already
            BackendKind::Xla | BackendKind::Sharded | BackendKind::Remote => {
                Err(EngineError::Spec {
                    field: "autoscale",
                    detail: "autoscale shards must be ideal|parasitic|fabric".into(),
                })
            }
        }
    }

    /// Build the concrete [`ShardedEngine`] this spec describes, on the
    /// current thread — for exhibits and tests that need shard-level
    /// introspection beyond the `Engine` trait. Elastic when an
    /// autoscale section is present, fixed-fleet otherwise.
    pub fn build_sharded(&self) -> crate::Result<ShardedEngine> {
        self.validate()?;
        anyhow::ensure!(
            self.kind == BackendKind::Sharded,
            "build_sharded needs a sharded spec (got backend '{}')",
            self.kind.name()
        );
        if let Some(auto) = &self.autoscale {
            let mut inner = self.clone();
            inner.kind = self.sharding.inner;
            inner.autoscale = None;
            inner.remote = RemoteSpec::default();
            let layers = inner.resolve_layers()?;
            let builder = self.build_shard_builder(&layers)?;
            ShardedEngine::elastic_with(
                builder,
                layers,
                auto.min_shards,
                auto.pulse_budget,
                self.remote_factories()?,
            )
        } else {
            let mut inner = self.clone();
            inner.kind = self.sharding.inner;
            inner.workers = self.sharding.shards;
            inner.sharding.canary = 0.0;
            inner.remote = RemoteSpec::default();
            let mut factories = if self.sharding.shards == 0 {
                Vec::new()
            } else {
                inner.build_factories()?
            };
            factories.extend(self.remote_factories()?);
            if self.sharding.canary > 0.0 {
                factories.push(self.canary_factory()?);
                return ShardedEngine::with_canary(factories, self.sharding.canary);
            }
            ShardedEngine::new(factories)
        }
    }

    /// Build and construct an engine on the current thread (examples,
    /// exhibits and tests that don't need the coordinator).
    pub fn build_engine(&self) -> crate::Result<Box<dyn Engine>> {
        let factory = self.build()?;
        factory()
    }
}

fn parse_autoscale_bounds(s: &str) -> Result<(usize, usize), EngineError> {
    let bad = || EngineError::Spec {
        field: "autoscale",
        detail: format!("--autoscale expects min,max shard bounds (e.g. 1,4), got '{s}'"),
    };
    let (a, b) = s.split_once(',').ok_or_else(bad)?;
    let min: usize = a.trim().parse().map_err(|_| bad())?;
    let max: usize = b.trim().parse().map_err(|_| bad())?;
    Ok((min, max))
}

fn parse_opt_usize(args: &Args, key: &'static str) -> Result<Option<usize>, EngineError> {
    match args.get(key) {
        None => Ok(None),
        Some(v) => v.parse::<usize>().map(Some).map_err(|_| EngineError::Spec {
            field: key,
            detail: format!("expects a non-negative integer, got '{v}'"),
        }),
    }
}

fn obj_entries<'a>(
    v: &'a Json,
    what: &str,
) -> Result<&'a [(String, Json)], EngineError> {
    match v {
        Json::Obj(entries) => Ok(entries),
        _ => Err(EngineError::Json(format!("'{what}' must be an object"))),
    }
}

fn json_usize(v: &Json, field: &str) -> Result<usize, EngineError> {
    v.as_usize()
        .ok_or_else(|| EngineError::Json(format!("field '{field}': expected a non-negative integer")))
}

fn json_f64(v: &Json, field: &str) -> Result<f64, EngineError> {
    v.as_f64()
        .ok_or_else(|| EngineError::Json(format!("field '{field}': expected a number")))
}

fn json_str<'a>(v: &'a Json, field: &str) -> Result<&'a str, EngineError> {
    v.as_str()
        .ok_or_else(|| EngineError::Json(format!("field '{field}': expected a string")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(line: &str) -> Args {
        Args::parse(line.split_whitespace().map(String::from))
    }

    #[test]
    fn defaults_match_the_historical_serve_configuration() {
        let spec = EngineSpec::default();
        assert_eq!(spec.kind, BackendKind::Ideal);
        assert_eq!(spec.workers, 2);
        assert_eq!((spec.array.rows, spec.array.cols), (64, 128));
        assert_eq!(spec.batching.capacity, 64);
        assert_eq!(spec.fabric.grid_rows, 2);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        let spec = EngineSpec::new(BackendKind::Fabric)
            .with_workers(3)
            .with_network(NetworkSource::Template)
            .with_grid(3, 5)
            .with_tile(16, 48)
            .with_fabric_max_batch(256)
            .with_batching(32, 500);
        let text = spec.to_json();
        let parsed = EngineSpec::from_json(&text).expect("roundtrip parse");
        assert_eq!(parsed, spec);
        // serialization is a fixed point
        assert_eq!(parsed.to_json(), text);
    }

    #[test]
    fn json_span_survives_roundtrip() {
        let spec = EngineSpec::new(BackendKind::Parasitic)
            .with_batching(32, 200)
            .with_array(ArraySpec {
                rows: 32,
                cols: 144,
                span: Some(121),
                ..ArraySpec::default()
            });
        let parsed = EngineSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(parsed.array.span, Some(121));
        assert_eq!(parsed, spec);
    }

    #[test]
    fn json_missing_fields_take_defaults() {
        let spec = EngineSpec::from_json(r#"{"backend": "fabric"}"#).unwrap();
        assert_eq!(spec.kind, BackendKind::Fabric);
        assert_eq!(spec.fabric, FabricSpec::default());
        assert_eq!(spec.workers, 2);
    }

    #[test]
    fn json_rejects_unknown_and_ill_typed_fields() {
        let err = EngineSpec::from_json(r#"{"backnd": "fabric"}"#).unwrap_err();
        assert!(err.to_string().contains("unknown field 'backnd'"), "{err}");
        let err = EngineSpec::from_json(r#"{"array": {"rows": "64"}}"#).unwrap_err();
        assert!(err.to_string().contains("array.rows"), "{err}");
        let err = EngineSpec::from_json(r#"{"backend": "warp"}"#).unwrap_err();
        assert!(err.to_string().contains("unknown backend kind"), "{err}");
        assert!(EngineSpec::from_json("[1]").is_err());
    }

    #[test]
    fn flags_select_backends() {
        assert_eq!(
            EngineSpec::from_args(&args("serve")).unwrap().kind,
            BackendKind::Ideal
        );
        assert_eq!(
            EngineSpec::from_args(&args("serve --parasitic")).unwrap().kind,
            BackendKind::Parasitic
        );
        let spec = EngineSpec::from_args(&args("serve --fabric --grid 3")).unwrap();
        assert_eq!(spec.kind, BackendKind::Fabric);
        assert_eq!((spec.fabric.grid_rows, spec.fabric.grid_cols), (3, 3));
        let spec = EngineSpec::from_args(&args("serve --xla --workers 4")).unwrap();
        assert_eq!(spec.kind, BackendKind::Xla);
        assert_eq!(spec.network, NetworkSource::Artifact);
        assert_eq!(spec.workers, 4);
    }

    #[test]
    fn each_conflicting_flag_combination_has_its_message() {
        let err = EngineSpec::from_args(&args("serve --xla --fabric")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--xla and --fabric are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --xla --parasitic")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--xla and --parasitic are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --fabric --parasitic")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--fabric and --parasitic are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --grid 2")).unwrap_err();
        assert_eq!(err.to_string(), "--grid requires --fabric");
        let err = EngineSpec::from_args(&args("serve --fabric --grid 0")).unwrap_err();
        assert_eq!(err, EngineError::EmptyGrid { rows: 0, cols: 0 });
    }

    #[test]
    fn shards_flag_wraps_the_selected_backend() {
        let spec = EngineSpec::from_args(&args("serve --fabric --shards 4")).unwrap();
        assert_eq!(spec.kind, BackendKind::Sharded);
        assert_eq!(
            spec.sharding,
            ShardSpec {
                shards: 4,
                inner: BackendKind::Fabric,
                canary: 0.0,
            }
        );
        assert_eq!(spec.effective_kind(), BackendKind::Fabric);
        assert_eq!(spec.workers, 1, "sharding defaults to one coordinator worker");
        let spec = EngineSpec::from_args(&args("serve --shards 2 --workers 3")).unwrap();
        assert_eq!(spec.sharding.inner, BackendKind::Ideal);
        assert_eq!(spec.workers, 3, "--workers overrides the sharded default");
    }

    #[test]
    fn shards_flag_conflicts_and_zero_are_typed_errors() {
        let err = EngineSpec::from_args(&args("serve --xla --shards 2")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--shards and --xla are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --shards 0")).unwrap_err();
        assert_eq!(err, EngineError::ZeroShards);
        assert_eq!(err.to_string(), "shard count must be at least 1");
        let err = EngineSpec::from_args(&args("serve --shards two")).unwrap_err();
        assert!(
            err.to_string().contains("'shards'") && err.to_string().contains("two"),
            "{err}"
        );
    }

    #[test]
    fn remote_flag_selects_the_remote_backend() {
        let spec = EngineSpec::from_args(&args("serve --remote 10.0.0.1:9000")).unwrap();
        assert_eq!(spec.kind, BackendKind::Remote);
        assert_eq!(spec.remote.addrs, vec!["10.0.0.1:9000".to_string()]);
        assert_eq!(spec.workers, 1, "a shard host serves one connection");
        assert_eq!(
            spec.remote.connect_timeout_ms,
            RemoteSpec::default().connect_timeout_ms
        );
        // several hosts and no local shards: an all-remote sharded fleet
        let spec = EngineSpec::from_args(&args(
            "serve --remote 10.0.0.1:9000,10.0.0.2:9000",
        ))
        .unwrap();
        assert_eq!(spec.kind, BackendKind::Sharded);
        assert_eq!(spec.sharding.shards, 0, "no local shards");
        assert_eq!(spec.remote.addrs.len(), 2);
        // the builder mirrors the flags
        let spec = EngineSpec::new(BackendKind::Ideal).with_remote(["unix:/tmp/s.sock"]);
        assert_eq!(spec.kind, BackendKind::Remote);
        assert_eq!(spec.workers, 1);
    }

    #[test]
    fn remote_addresses_join_a_sharded_fleet() {
        let spec =
            EngineSpec::from_args(&args("serve --shards 1 --remote 10.0.0.1:9000")).unwrap();
        assert_eq!(spec.kind, BackendKind::Sharded);
        assert_eq!(spec.sharding.shards, 1, "one local shard");
        assert_eq!(spec.sharding.inner, BackendKind::Ideal);
        assert_eq!(spec.remote.addrs, vec!["10.0.0.1:9000".to_string()]);
        // ...and the elastic fleet takes remote extras too
        let spec = EngineSpec::from_args(&args(
            "serve --autoscale 1,4 --remote unix:/tmp/shard.sock",
        ))
        .unwrap();
        assert_eq!(spec.kind, BackendKind::Sharded);
        assert!(spec.autoscale.is_some());
        assert_eq!(spec.remote.addrs.len(), 1);
    }

    #[test]
    fn remote_flag_conflicts_and_misuse_are_typed_errors() {
        let err = EngineSpec::from_args(&args("serve --xla --remote h:1")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--remote and --xla are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --fabric --remote h:1")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--remote and --fabric are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --parasitic --remote h:1")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--remote and --parasitic are mutually exclusive — pick one backend"
        );
        // ...but through the sharded wrapper the fidelity flag shapes the
        // *local* shards, so it composes
        let spec =
            EngineSpec::from_args(&args("serve --fabric --shards 2 --remote h:1")).unwrap();
        assert_eq!(spec.sharding.inner, BackendKind::Fabric);
        // an explicitly zero-shard fleet is still an error, remote or not
        let err = EngineSpec::from_args(&args("serve --shards 0 --remote h:1")).unwrap_err();
        assert_eq!(err, EngineError::ZeroShards);
        // malformed endpoints are typed, with the offender named
        let err = EngineSpec::from_args(&args("serve --remote nonsense")).unwrap_err();
        assert_eq!(err, EngineError::BadRemoteAddr("nonsense".into()));
        let err = EngineSpec::from_args(&args("serve --remote host:notaport")).unwrap_err();
        assert!(matches!(err, EngineError::BadRemoteAddr(_)), "{err}");
        // a remote fleet takes exactly one coordinator worker
        let err =
            EngineSpec::from_args(&args("serve --remote h:1 --workers 2")).unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "workers", .. })
                && err.to_string().contains("one connection at a time"),
            "{err}"
        );
    }

    #[test]
    fn remote_spec_validation_pins_the_shapes() {
        // the remote backend drives exactly one host
        let mut spec = EngineSpec::new(BackendKind::Remote);
        spec.workers = 1;
        let err = spec.clone().validate().unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "remote", .. })
                && err.to_string().contains("exactly one shard host"),
            "{err}"
        );
        spec.remote.addrs = vec!["h:1".into()];
        assert!(spec.validate().is_ok());
        // explicit layers have nowhere to go — the host owns the network
        let err = spec
            .clone()
            .with_layers(vec![crate::report::table2::template_layer()])
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "layers", .. }),
            "{err}"
        );
        // addresses on a plain local backend are a contradiction
        let mut stray = EngineSpec::new(BackendKind::Ideal);
        stray.remote.addrs = vec!["h:1".into()];
        stray.workers = 1;
        let err = stray.validate().unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "remote", .. })
                && err.to_string().contains("remote or sharded"),
            "{err}"
        );
        // zero timeouts would hang or spin — rejected
        let mut spec = EngineSpec::new(BackendKind::Remote);
        spec.workers = 1;
        spec.remote.addrs = vec!["h:1".into()];
        spec.remote.io_timeout_ms = 0;
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("timeouts"), "{err}");
        // remote cannot be the sharded *inner* (it joins via addrs)
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_shards(2, BackendKind::Remote)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "sharding", .. })
                && err.to_string().contains("remote.addrs"),
            "{err}"
        );
    }

    #[test]
    fn remote_section_survives_json_roundtrip() {
        let mut spec = EngineSpec::new(BackendKind::Ideal).with_shards(1, BackendKind::Ideal);
        spec.remote = RemoteSpec {
            addrs: vec!["10.0.0.1:9000".into(), "unix:/tmp/shard.sock".into()],
            connect_timeout_ms: 250,
            io_timeout_ms: 1_000,
        };
        spec.workers = 1;
        let text = spec.to_json();
        let parsed = EngineSpec::from_json(&text).expect("roundtrip parse");
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_json(), text, "serialization is a fixed point");
        // sparse section takes defaults for the rest
        let spec = EngineSpec::from_json(
            r#"{"backend":"remote","workers":1,"remote":{"addrs":["h:1"]}}"#,
        )
        .unwrap();
        assert_eq!(spec.kind, BackendKind::Remote);
        assert_eq!(spec.remote.io_timeout_ms, RemoteSpec::default().io_timeout_ms);
        // unknown subfields and ill-typed addrs are rejected
        let err = EngineSpec::from_json(r#"{"remote":{"adrs":["h:1"]}}"#).unwrap_err();
        assert!(err.to_string().contains("remote.adrs"), "{err}");
        let err = EngineSpec::from_json(r#"{"remote":{"addrs":"h:1"}}"#).unwrap_err();
        assert!(err.to_string().contains("remote.addrs"), "{err}");
        // a bad endpoint in a JSON spec is the same typed error the CLI gets
        let err = EngineSpec::from_json(
            r#"{"backend":"remote","workers":1,"remote":{"addrs":["nope"]}}"#,
        )
        .unwrap_err();
        assert_eq!(err, EngineError::BadRemoteAddr("nope".into()));
    }

    #[test]
    fn placement_flag_selects_the_strategy() {
        let spec =
            EngineSpec::from_args(&args("serve --fabric --placement locality")).unwrap();
        assert_eq!(spec.fabric.placement, PlacementStrategy::Locality);
        // …also through the sharded wrapper (kind is Sharded by then)
        let spec = EngineSpec::from_args(&args(
            "serve --fabric --shards 2 --placement locality",
        ))
        .unwrap();
        assert_eq!(spec.fabric.placement, PlacementStrategy::Locality);
        let err = EngineSpec::from_args(&args("serve --placement locality")).unwrap_err();
        assert_eq!(err.to_string(), "--placement requires --fabric");
        let err =
            EngineSpec::from_args(&args("serve --fabric --placement diagonal")).unwrap_err();
        assert_eq!(err, EngineError::UnknownPlacement("diagonal".into()));
    }

    #[test]
    fn sharded_spec_validation() {
        assert!(EngineSpec::new(BackendKind::Ideal)
            .with_shards(2, BackendKind::Ideal)
            .validate()
            .is_ok());
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_shards(0, BackendKind::Ideal)
            .validate()
            .unwrap_err();
        assert_eq!(err, EngineError::ZeroShards);
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_shards(2, BackendKind::Sharded)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "sharding", .. })
                && err.to_string().contains("nest"),
            "{err}"
        );
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_shards(2, BackendKind::Xla)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "sharding", .. })
                && err.to_string().contains("thread-affine"),
            "{err}"
        );
        // the batch-capacity cap flows through to the inner backend
        let err = EngineSpec::new(BackendKind::Fabric)
            .with_fabric_max_batch(16)
            .with_shards(2, BackendKind::Fabric)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "batching", .. }),
            "{err}"
        );
    }

    #[test]
    fn sharded_and_placement_survive_json_roundtrip() {
        let spec = EngineSpec::new(BackendKind::Fabric)
            .with_grid(3, 3)
            .with_placement(PlacementStrategy::Locality)
            .with_shards(4, BackendKind::Fabric)
            .with_batching(32, 100);
        let text = spec.to_json();
        let parsed = EngineSpec::from_json(&text).expect("roundtrip parse");
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_json(), text);
        let spec = EngineSpec::from_json(
            r#"{"backend":"sharded","sharding":{"shards":3,"inner":"fabric"}}"#,
        )
        .unwrap();
        assert_eq!(spec.kind, BackendKind::Sharded);
        assert_eq!(spec.sharding.shards, 3);
        assert_eq!(spec.effective_kind(), BackendKind::Fabric);
        let err = EngineSpec::from_json(r#"{"fabric":{"placement":"diag"}}"#).unwrap_err();
        assert!(err.to_string().contains("placement"), "{err}");
    }

    #[test]
    fn autoscale_flag_builds_an_elastic_sharded_spec() {
        let spec = EngineSpec::from_args(&args("serve --autoscale 1,4")).unwrap();
        assert_eq!(spec.kind, BackendKind::Sharded);
        assert_eq!(spec.sharding.inner, BackendKind::Ideal);
        let auto = spec.autoscale.expect("autoscale section attached");
        assert_eq!((auto.min_shards, auto.max_shards), (1, 4));
        // watermarks track the default batch capacity (64)
        assert_eq!(auto.high_watermark, 96);
        assert_eq!(auto.low_watermark, 16);
        assert_eq!(spec.workers, 1, "elastic fleet defaults to one worker");
        // wraps whatever backend the other flags selected
        let spec = EngineSpec::from_args(&args("serve --fabric --autoscale 2,3")).unwrap();
        assert_eq!(spec.sharding.inner, BackendKind::Fabric);
        assert_eq!(spec.sharding.shards, 2, "fleet starts at min");
        // watermarks follow an explicit --batch
        let spec = EngineSpec::from_args(&args("serve --batch 16 --autoscale 1,2")).unwrap();
        assert_eq!(spec.autoscale.unwrap().high_watermark, 24);
    }

    #[test]
    fn autoscale_conflicts_and_malformed_bounds_are_typed_errors() {
        let err = EngineSpec::from_args(&args("serve --xla --autoscale 1,4")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--autoscale and --xla are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --shards 2 --autoscale 1,4")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--autoscale and --shards are mutually exclusive — pick one backend"
        );
        let err = EngineSpec::from_args(&args("serve --autoscale four")).unwrap_err();
        assert!(
            err.to_string().contains("min,max") && err.to_string().contains("four"),
            "{err}"
        );
        let err = EngineSpec::from_args(&args("serve --autoscale 0,4")).unwrap_err();
        assert_eq!(err, EngineError::ZeroShards);
        let err = EngineSpec::from_args(&args("serve --autoscale 4,2")).unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "autoscale", .. })
                && err.to_string().contains("exceeds"),
            "{err}"
        );
    }

    #[test]
    fn canary_flag_rides_a_sharded_fleet() {
        let spec = EngineSpec::from_args(&args("serve --shards 2 --canary 0.25")).unwrap();
        assert_eq!(spec.kind, BackendKind::Sharded);
        assert_eq!(spec.sharding.canary, 0.25);
        assert_eq!(spec.sharding.inner, BackendKind::Ideal);
        assert!(
            spec.describe().contains("parasitic canary sampling 25%"),
            "{}",
            spec.describe()
        );
        // the canary section survives the JSON roundtrip
        let parsed = EngineSpec::from_json(&spec.to_json()).expect("roundtrip");
        assert_eq!(parsed.sharding.canary, 0.25);
        // a canary needs a sharded fleet to ride
        let err = EngineSpec::from_args(&args("serve --canary 0.25")).unwrap_err();
        assert!(err.to_string().contains("--shards"), "{err}");
        // mutually exclusive with autoscale (the canary slot is pinned)
        let err =
            EngineSpec::from_args(&args("serve --autoscale 1,4 --canary 0.5")).unwrap_err();
        assert_eq!(
            err,
            EngineError::Conflict {
                first: "--canary",
                second: "--autoscale",
            }
        );
        // sampling fraction is a probability
        let err = EngineSpec::from_args(&args("serve --shards 2 --canary 1.5")).unwrap_err();
        assert!(err.to_string().contains("(0, 1]"), "{err}");
        let err = EngineSpec::from_args(&args("serve --shards 2 --canary lots")).unwrap_err();
        assert!(err.to_string().contains("sampling fraction"), "{err}");
        // the divergence compare needs ideal primaries
        let err = EngineSpec::from_args(&args("serve --fabric --shards 2 --canary 0.5"))
            .unwrap_err();
        assert!(err.to_string().contains("must be ideal"), "{err}");
        // JSON path hits the same validation
        let err = EngineSpec::from_json(
            r#"{"backend":"sharded","sharding":{"shards":2,"canary":2.0}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("(0, 1]"), "{err}");
    }

    #[test]
    fn autoscale_section_survives_json_roundtrip() {
        let spec = EngineSpec::new(BackendKind::Fabric)
            .with_grid(2, 2)
            .with_batching(32, 100)
            .with_autoscale(AutoscaleSpec {
                min_shards: 2,
                max_shards: 6,
                high_watermark: 48,
                low_watermark: 8,
                cooldown: 3,
                pulse_budget: 5000,
            });
        let text = spec.to_json();
        let parsed = EngineSpec::from_json(&text).expect("roundtrip parse");
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_json(), text, "serialization is a fixed point");
        // absent section stays None (and renders as null)
        let none = EngineSpec::from_json(r#"{"autoscale": null}"#).unwrap();
        assert_eq!(none.autoscale, None);
        // sparse section takes defaults for the rest
        let spec = EngineSpec::from_json(
            r#"{"backend":"sharded","autoscale":{"min_shards":2,"max_shards":3}}"#,
        )
        .unwrap();
        let auto = spec.autoscale.unwrap();
        assert_eq!((auto.min_shards, auto.max_shards), (2, 3));
        assert_eq!(auto.cooldown, AutoscaleSpec::default().cooldown);
        // unknown subfields rejected
        let err =
            EngineSpec::from_json(r#"{"backend":"sharded","autoscale":{"watermark":9}}"#)
                .unwrap_err();
        assert!(err.to_string().contains("autoscale.watermark"), "{err}");
        // autoscale on a non-sharded backend is rejected
        let err = EngineSpec::from_json(r#"{"backend":"ideal","autoscale":{}}"#).unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "autoscale", .. })
                && err.to_string().contains("sharded"),
            "{err}"
        );
        // degenerate watermarks rejected
        let err = EngineSpec::from_json(
            r#"{"backend":"sharded","autoscale":{"high_watermark":4,"low_watermark":4}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("watermark"), "{err}");
        // an explicit fixed shard count that disagrees with the elastic
        // floor would be silently ignored — rejected instead
        let err = EngineSpec::from_json(
            r#"{"backend":"sharded","sharding":{"shards":3},
                "autoscale":{"min_shards":1,"max_shards":4}}"#,
        )
        .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "autoscale", .. })
                && err.to_string().contains("min_shards"),
            "{err}"
        );
        // watermark band stays valid even for a 1-image batch capacity
        let tiny = AutoscaleSpec::for_batch(1, 2, 1);
        assert!(tiny.validate().is_ok());
        assert!(tiny.low_watermark < tiny.high_watermark);
    }

    #[test]
    fn swap_section_parses_roundtrips_and_conflicts() {
        // flags: --swap-to attaches the reprogramming target
        let spec = EngineSpec::from_args(&args("serve --swap-to template")).unwrap();
        assert_eq!(spec.swap_to, Some(NetworkSource::Template));
        let spec = EngineSpec::from_args(&args("serve --fabric --shards 2 --swap-to auto"))
            .unwrap();
        assert_eq!(spec.swap_to, Some(NetworkSource::Auto));
        // JSON roundtrip (fixed point, Null when absent)
        let spec = EngineSpec::new(BackendKind::Fabric).with_swap_to(NetworkSource::Template);
        let text = spec.to_json();
        let parsed = EngineSpec::from_json(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_json(), text);
        let none = EngineSpec::from_json(r#"{"swap_to": null}"#).unwrap();
        assert_eq!(none.swap_to, None);
        let spec =
            EngineSpec::from_json(r#"{"backend":"fabric","swap_to":"template"}"#).unwrap();
        assert_eq!(spec.swap_to, Some(NetworkSource::Template));
    }

    #[test]
    fn swap_to_with_xla_is_a_typed_error() {
        let err = EngineSpec::from_args(&args("serve --xla --swap-to template")).unwrap_err();
        assert_eq!(
            err.to_string(),
            "--swap-to and --xla are mutually exclusive — pick one backend"
        );
        // same guard through validation (e.g. a JSON base selecting xla)
        let err = EngineSpec::new(BackendKind::Xla)
            .with_swap_to(NetworkSource::Artifact)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "swap_to", .. })
                && err.to_string().contains("baked into the AOT graph"),
            "{err}"
        );
        // unknown target names stay typed
        let err = EngineSpec::from_args(&args("serve --swap-to warp")).unwrap_err();
        assert_eq!(err, EngineError::UnknownNetwork("warp".into()));
    }

    #[test]
    fn batch_flag_keeps_the_historical_contract() {
        let spec = EngineSpec::from_args(&args("serve --batch 16")).unwrap();
        assert_eq!(spec.batching.capacity, 16);
        assert_eq!(spec.array.rows, 64);
        let spec = EngineSpec::from_args(&args("serve --batch 256")).unwrap();
        assert_eq!(spec.batching.capacity, 64);
        assert_eq!(spec.array.rows, 256);
    }

    #[test]
    fn batch_flag_does_not_clobber_an_explicit_spec_file_base() {
        let mut spec = EngineSpec::from_json(
            r#"{"backend":"fabric","array":{"rows":256},"batching":{"capacity":128}}"#,
        )
        .unwrap();
        spec.apply_args(&args("serve --batch 16"), true).unwrap();
        assert_eq!(spec.batching.capacity, 16);
        assert_eq!(spec.array.rows, 256, "spec-file array design untouched");
        // without a spec-file base, the historical contract still applies
        let mut bare = EngineSpec::default();
        bare.apply_args(&args("serve --batch 16"), false).unwrap();
        assert_eq!(bare.batching.capacity, 16);
        assert_eq!(bare.array.rows, 64);
    }

    #[test]
    fn batch_capacity_may_not_exceed_the_backend_max_batch() {
        // would previously pass validation and then panic the worker
        // thread inside BinaryLayer::run_batch ("batch exceeds rows")
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 128,
                ..ArraySpec::default()
            })
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "batching", .. }),
            "{err}"
        );
        let err = EngineSpec::new(BackendKind::Fabric)
            .with_fabric_max_batch(16)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "batching", .. }),
            "{err}"
        );
        let err = EngineSpec::new(BackendKind::Xla)
            .with_batching(128, 200)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "batching", .. }),
            "{err}"
        );
        // shrinking the capacity to fit makes each of them valid
        assert!(EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 128,
                ..ArraySpec::default()
            })
            .with_batching(32, 200)
            .validate()
            .is_ok());
    }

    #[test]
    fn xla_spec_rejects_template_network() {
        let err = EngineSpec::new(BackendKind::Xla)
            .with_network(NetworkSource::Template)
            .validate()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Spec { field: "network", .. }),
            "{err}"
        );
        assert!(EngineSpec::new(BackendKind::Xla).validate().is_ok(), "auto is fine");
    }

    #[test]
    fn malformed_numbers_are_typed_errors() {
        let err = EngineSpec::from_args(&args("serve --workers abc")).unwrap_err();
        assert!(
            err.to_string().contains("'workers'") && err.to_string().contains("abc"),
            "{err}"
        );
        let err = EngineSpec::from_args(&args("serve --workers 0")).unwrap_err();
        assert_eq!(err, EngineError::ZeroWorkers);
    }

    #[test]
    fn validation_rejects_degenerate_specs() {
        let err = EngineSpec::new(BackendKind::Fabric)
            .with_grid(0, 1)
            .validate()
            .unwrap_err();
        assert_eq!(err, EngineError::EmptyGrid { rows: 0, cols: 1 });
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                span: Some(500),
                ..ArraySpec::default()
            })
            .validate()
            .unwrap_err();
        assert_eq!(err, EngineError::BadSpan { span: 500, n_col: 128 });
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                line_config: 7,
                ..ArraySpec::default()
            })
            .validate()
            .unwrap_err();
        assert_eq!(err, EngineError::UnknownLineConfig("7".into()));
        let err = EngineSpec::new(BackendKind::Ideal)
            .with_layers(vec![])
            .validate()
            .unwrap_err();
        assert!(matches!(err, EngineError::Spec { field: "layers", .. }));
    }

    #[test]
    fn coordinator_config_mirrors_the_batching_policy() {
        let spec = EngineSpec::default().with_batching(8, 1000);
        let cfg = spec.coordinator_config();
        assert_eq!(cfg.batch_capacity, 8);
        assert_eq!(cfg.linger, Duration::from_micros(1000));
    }

    #[test]
    fn describe_names_each_backend() {
        assert!(EngineSpec::new(BackendKind::Ideal).describe().contains("Ideal"));
        assert!(EngineSpec::new(BackendKind::Xla).describe().contains("XLA"));
        assert!(EngineSpec::new(BackendKind::Fabric)
            .describe()
            .contains("2×2 subarray grid"));
        let d = EngineSpec::new(BackendKind::Fabric)
            .with_shards(4, BackendKind::Fabric)
            .describe();
        assert!(d.contains("4 shard(s)") && d.contains("fabric"), "{d}");
    }

    #[test]
    fn network_grammar_roundtrips_and_autosizes_the_array() {
        // parse(spec_str()) is the identity for every source family
        let sources = "auto template artifact multibit:1:lowpower multibit:3:lowpower \
                       multibit:2:area conv:4x3x3:t5 conv:2x5x5:t12";
        for s in sources.split_whitespace() {
            let parsed = NetworkSource::parse(s).expect(s);
            assert_eq!(parsed.spec_str(), s, "canonical form is a fixed point");
            assert_eq!(NetworkSource::parse(&parsed.spec_str()).unwrap(), parsed);
        }
        // defaults: lowpower scheme, majority-vote conv threshold
        let mb = NetworkSource::parse("multibit:2").unwrap();
        assert_eq!(mb.spec_str(), "multibit:2:lowpower");
        let conv = NetworkSource::parse("conv:4x3x3").unwrap();
        assert_eq!(conv.spec_str(), "conv:4x3x3:t5");

        // the CLI path grows the subarray to fit the lowered layer
        let spec = EngineSpec::from_args(&args("serve --network multibit:3")).unwrap();
        assert_eq!(spec.network.input_expansion(), 7);
        assert!(spec.array.cols >= 121 * 7, "cols {} too narrow", spec.array.cols);
        let spec = EngineSpec::from_args(&args("serve --network conv:4x3x3")).unwrap();
        assert!(!spec.network.is_classifier());
        assert_eq!(spec.network.dense_shape(), (121, 4 * 9 * 9));
        assert!(spec.array.cols >= 4 * 9 * 9);

        // and the network survives the JSON spec roundtrip
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_network(NetworkSource::parse("multibit:2:area").unwrap());
        let parsed = EngineSpec::from_json(&spec.to_json()).expect("roundtrip parse");
        assert_eq!(parsed.network, spec.network);
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_network(NetworkSource::parse("conv:2x5x5:t12").unwrap());
        let parsed = EngineSpec::from_json(&spec.to_json()).expect("roundtrip parse");
        assert_eq!(parsed.network, spec.network);
    }

    #[test]
    fn network_grammar_rejects_malformed_specs() {
        let bad = "multibit multibit:0 multibit:9 multibit:2:fast conv conv:4x3 \
                   conv:0x3x3 conv:4x12x3 conv:4x3x3:5 sawtooth";
        for s in bad.split_whitespace() {
            assert!(NetworkSource::parse(s).is_err(), "'{s}' should not parse");
        }
        // infeasible scheme/bits combinations die in validate(), at parse
        // time for the CLI path — never in a worker
        let err = EngineSpec::from_args(&args("serve --network multibit:4:area")).unwrap_err();
        assert!(err.to_string().contains("5 V"), "{err}");
        assert!(EngineSpec::from_args(&args("serve --network multibit:8")).is_ok());
    }

    #[test]
    fn swap_targets_must_share_substrate_geometry() {
        // same dense geometry: template -> template is fine
        let spec = EngineSpec::from_args(&args("serve --shards 2 --swap-to template")).unwrap();
        assert_eq!(spec.swap_to, Some(NetworkSource::Template));
        // the unary expansion changes the column count under resident cells
        let err = EngineSpec::from_args(&args("serve --network template --swap-to multibit:2"))
            .unwrap_err();
        assert!(err.to_string().contains("geometry"), "{err}");
        let err = EngineSpec::from_args(&args("serve --swap-to conv:2x3x3")).unwrap_err();
        assert!(err.to_string().contains("geometry"), "{err}");
    }
}

//! Property wall for the parasitic fabric fidelity: every placed tile's
//! electrical step is **bit-exact** (f64 `to_bits`) with the cell-level
//! scalar oracle [`Subarray::tmvm_rows_scalar`] evaluated on the same
//! [`ArrayDesign`] — across arbitrary grids, tilings and
//! non-lane-multiple widths — and the static noise-margin machinery the
//! fidelity reports through is internally consistent
//! ([`max_rows_for_nm`] really is the NM boundary, margins shrink
//! monotonically with row count, the executor's `margin_min` is the min
//! over its tile designs).

use xpoint_imc::analysis::{ladder_thevenin, max_rows_for_nm, noise_margin, ArrayDesign};
use xpoint_imc::array::{Level, Subarray, TmvmMode, TmvmOutcome};
use xpoint_imc::fabric::{
    place_layers, tile_step_parasitic, vdd_for_theta, FabricConfig, FabricExecutor, Fidelity,
};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize) -> BinaryLayer {
    let theta = rng.range(1, 4);
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
            .collect(),
        theta,
    )
}

/// A random layer chain with matching inner dimensions.
fn random_chain(rng: &mut Pcg32, l: usize, lo: usize, hi: usize) -> Vec<BinaryLayer> {
    let dims: Vec<usize> = (0..=l).map(|_| rng.range(lo, hi)).collect();
    (0..l)
        .map(|k| random_layer(rng, dims[k + 1], dims[k]))
        .collect()
}

/// Every parasitic tile step — for random grids, tile geometries and
/// layer shapes (so tiles cover full, partial and non-lane-multiple
/// row/column spans) — produces per-row currents, the current sum and
/// the RESET-violation count bit-identical to the scalar oracle run on
/// the tile's own [`ArrayDesign`] (position-dependent driver resistance,
/// engaged span), with the tile padded to the full subarray the way the
/// physical placement realizes it.
#[test]
fn parasitic_tile_steps_are_bit_exact_with_the_scalar_oracle() {
    forall(
        Config::default().cases(40),
        "parasitic tile step vs scalar oracle",
        |rng: &mut Pcg32| {
            let gr = rng.range(1, 4);
            let gc = rng.range(1, 4);
            let tr = rng.range(3, 14);
            let tc = rng.range(3, 14);
            let l = rng.range(1, 4);
            // dims up to ~2.3 tiles per axis: partial edge tiles abound
            let layers = random_chain(rng, l, 2, 2 * tr.max(tc) + 4);
            let cfg =
                FabricConfig::new(gr, gc, tr, tc).with_fidelity(Fidelity::Parasitic);
            let p = cfg.device;
            let placement =
                place_layers(&layers, &cfg).map_err(|e| format!("placement: {e:#}"))?;

            // one random input vector per layer, sliced per tile
            let x_full: Vec<Vec<bool>> = layers
                .iter()
                .map(|layer| (0..layer.n_in()).map(|_| rng.bernoulli(0.5)).collect())
                .collect();

            for tile in &placement.tiles {
                let v_dd = vdd_for_theta(layers[tile.layer].theta, &p);
                let x_slice = &x_full[tile.layer][tile.col_range.clone()];
                let design = cfg.tile_design(tile);

                // the fabric path: the executor's per-tile ladder + step
                let ladders: Vec<_> = (1..=tile.weights.len())
                    .map(|row| ladder_thevenin(&design, row))
                    .collect();
                let step = tile_step_parasitic(&tile.weights, x_slice, v_dd, &p, &ladders);

                // the oracle path: the tile padded onto its full subarray
                // (absent rows floated, absent columns undriven)
                let padded: Vec<Vec<bool>> = (0..design.n_row)
                    .map(|r| {
                        let mut row = vec![false; design.n_col];
                        if let Some(w) = tile.weights.get(r) {
                            row[..w.len()].copy_from_slice(w);
                        }
                        row
                    })
                    .collect();
                let mut x_pad = vec![false; design.n_col];
                x_pad[..x_slice.len()].copy_from_slice(x_slice);
                let mut sa = Subarray::new(design.clone());
                sa.program_level(Level::Top, &padded);
                let rep = sa.tmvm_rows_scalar(
                    &x_pad,
                    0,
                    v_dd,
                    TmvmMode::Parasitic,
                    tile.weights.len(),
                );

                let mut oracle_sum = 0.0;
                let mut oracle_resets = 0u32;
                for (r, w_row) in tile.weights.iter().enumerate() {
                    if step.currents[r].to_bits() != rep.currents[r].to_bits() {
                        return Err(format!(
                            "layer {} tile ({},{}) row {r}: fabric {:e} vs oracle {:e}",
                            tile.layer,
                            tile.tile_row,
                            tile.tile_col,
                            step.currents[r],
                            rep.currents[r]
                        ));
                    }
                    oracle_sum += rep.currents[r];
                    if rep.outcomes[r] == TmvmOutcome::ResetViolation {
                        oracle_resets += 1;
                    }
                    // counts are the exact dot product, untouched by parasitics
                    let count = w_row.iter().zip(x_slice).filter(|(&w, &x)| w && x).count();
                    if step.counts[r] as usize != count {
                        return Err(format!("row {r}: count {} != {count}", step.counts[r]));
                    }
                }
                if step.current_sum.to_bits() != oracle_sum.to_bits() {
                    return Err(format!(
                        "current sum: fabric {:e} vs oracle {:e}",
                        step.current_sum, oracle_sum
                    ));
                }
                if step.reset_violations != oracle_resets {
                    return Err(format!(
                        "reset violations: fabric {} vs oracle {}",
                        step.reset_violations, oracle_resets
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The executor's reported `margin_min` is exactly the minimum
/// corner-case noise margin over its placed tiles' designs — and ideal
/// fidelity reports no window at all (`+∞`).
#[test]
fn executor_margin_is_the_min_over_tile_designs() {
    forall(
        Config::default().cases(25),
        "executor margin_min",
        |rng: &mut Pcg32| {
            let gr = rng.range(1, 4);
            let gc = rng.range(1, 4);
            let l = rng.range(1, 3);
            let layers = random_chain(rng, l, 3, 21);
            let cfg = FabricConfig::new(gr, gc, 8, 8).with_fidelity(Fidelity::Parasitic);
            let exec = FabricExecutor::new(layers.clone(), cfg.clone())
                .map_err(|e| format!("executor: {e:#}"))?;
            let expected = exec
                .placement()
                .tiles
                .iter()
                .map(|t| noise_margin(&cfg.tile_design(t)).noise_margin())
                .fold(f64::INFINITY, f64::min);
            if exec.margin_min().to_bits() != expected.to_bits() {
                return Err(format!(
                    "executor margin {:e} != tile-design min {:e}",
                    exec.margin_min(),
                    expected
                ));
            }
            // the run report carries the same number
            let n_in = layers[0].n_in();
            let images: Vec<Vec<bool>> =
                vec![(0..n_in).map(|_| rng.bernoulli(0.5)).collect()];
            let run = exec.run_batch(&images).map_err(|e| format!("run: {e:#}"))?;
            if run.margin_min.to_bits() != expected.to_bits() {
                return Err("run report margin diverges from executor".into());
            }
            // ideal fidelity models no electrical window
            let ideal = FabricExecutor::new(
                layers,
                FabricConfig::new(gr, gc, 8, 8).with_fidelity(Fidelity::Ideal),
            )
            .map_err(|e| format!("ideal executor: {e:#}"))?;
            if ideal.margin_min() != f64::INFINITY {
                return Err("ideal fidelity should report +inf margin".into());
            }
            Ok(())
        },
    );
}

/// Noise margin shrinks monotonically as rows are added (more parasitic
/// ladder to traverse), and [`max_rows_for_nm`] sits exactly on the
/// boundary: the returned row count still meets the target, one more row
/// does not (or the search reports 0 because even one row fails).
#[test]
fn margin_shrinks_with_rows_and_max_rows_is_the_boundary() {
    forall(
        Config::default().cases(60),
        "NM row boundary",
        |rng: &mut Pcg32| {
            let cols = rng.range(16, 257);
            let l_scale = 1.0 + rng.range(0, 5) as f64;
            let template =
                ArrayDesign::new(64, cols, LineConfig::config3(), l_scale, 1.0);
            let nm_at = |n_row: usize| -> f64 {
                let mut d = template.clone();
                d.n_row = n_row;
                noise_margin(&d).noise_margin()
            };
            // monotone non-increasing along a geometric row sweep
            let mut prev = f64::INFINITY;
            for n in [1usize, 2, 4, 16, 64, 256, 1024, 4096] {
                let nm = nm_at(n);
                if nm > prev {
                    return Err(format!(
                        "cols {cols} L{l_scale}: NM grew from {prev:e} to {nm:e} at {n} rows"
                    ));
                }
                prev = nm;
            }
            // the search result brackets the target exactly
            let target = 0.05 + 0.6 * rng.range(0, 1000) as f64 / 1000.0;
            let n = max_rows_for_nm(&template, target);
            if n == 0 {
                if nm_at(1) >= target {
                    return Err(format!("search gave 0 but one row meets NM {target}"));
                }
            } else if n < (1 << 24) {
                if nm_at(n) < target {
                    return Err(format!("{n} rows fails the target it was returned for"));
                }
                if nm_at(n + 1) >= target {
                    return Err(format!("{} rows still meets NM {target}", n + 1));
                }
            }
            Ok(())
        },
    );
}

//! Integration: NN layers on subarrays against their functional golden
//! models, and classification quality on the synthetic digit corpus.

use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::{Subarray, TmvmMode};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::nn::conv::BinaryConv2d;
use xpoint_imc::nn::dataset::{DigitGen, IMAGE_SIDE, TEST_SEED};
use xpoint_imc::nn::mlp::MlpOnSubarrays;
use xpoint_imc::nn::{BinaryLayer, BinaryMlp};
use xpoint_imc::report::table2::template_layer;

#[test]
fn template_layer_beats_chance_comfortably() {
    let layer = template_layer();
    let ds = DigitGen::new(TEST_SEED).dataset(500);
    let correct = ds
        .samples
        .iter()
        .filter(|s| layer.argmax(&s.pixels) == s.label)
        .count();
    let acc = correct as f64 / ds.len() as f64;
    assert!(acc > 0.5, "template accuracy {acc} (chance = 0.1)");
}

#[test]
fn hardware_batches_match_functional_on_digits() {
    let layer = template_layer();
    let ds = DigitGen::new(7).dataset(128);
    let design = ArrayDesign::new(64, 128, LineConfig::config3(), 3.0, 1.0).with_span(121);
    let mut sa = Subarray::new(design);
    for chunk in ds.samples.chunks(64) {
        let images: Vec<Vec<bool>> = chunk.iter().map(|s| s.pixels.clone()).collect();
        let run = layer.run_batch(&mut sa, &images, TmvmMode::Ideal);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(run.outputs[i], layer.forward(img));
        }
        assert!(run.steps.iter().all(|s| s.is_clean()));
    }
    // Table II accounting: 64-row batch finishes its 10 neuron steps in
    // 10·t_SET of array busy time
    let t_set = 80e-9;
    assert!(sa.ledger.steps == 20, "2 batches × 10 steps");
    assert!(sa.ledger.time > 20.0 * t_set * 0.9);
}

#[test]
fn mlp_pipeline_on_two_subarrays_matches_functional() {
    let mut gen = DigitGen::new(42);
    let images: Vec<Vec<bool>> = (0..16).map(|_| gen.next_sample().pixels).collect();

    // small trained-ish MLP: class templates as detectors + readout
    let l1 = template_layer(); // 10 detectors, theta 20
    let eye: Vec<Vec<bool>> = (0..10).map(|r| (0..10).map(|c| r == c).collect()).collect();
    let l2 = BinaryLayer::new(eye, 1);
    let mlp = BinaryMlp::new(l1, l2);

    let d1 = ArrayDesign::new(16, 128, LineConfig::config3(), 3.0, 1.0);
    let d2 = ArrayDesign::new(16, 16, LineConfig::config3(), 3.0, 1.0);
    let mut pipe = MlpOnSubarrays::new(mlp.clone(), d1, d2);
    let run = pipe.run_batch(&images, TmvmMode::Ideal);
    assert!(run.clean);
    assert_eq!(run.steps, 16 + 10);
    for (i, img) in images.iter().enumerate() {
        assert_eq!(run.outputs[i], mlp.forward(img), "image {i}");
    }
}

#[test]
fn conv_as_tmvm_runs_on_subarray() {
    // 3×3 binary edge filters over a digit image, through the im2col +
    // subarray path, against the direct convolution
    let mut gen = DigitGen::new(3);
    let img = gen.next_sample().pixels;
    let filters = vec![
        vec![true, true, true, false, false, false, false, false, false], // top bar
        vec![true, false, false, true, false, false, true, false, false], // left bar
    ];
    let conv = BinaryConv2d::new(filters, 3, 3, 2);
    let direct = conv.forward_direct(&img, IMAGE_SIDE, IMAGE_SIDE).unwrap();

    let patches = conv.im2col(&img, IMAGE_SIDE, IMAGE_SIDE).unwrap();
    let layer = conv.as_layer();
    let design = ArrayDesign::new(128, 16, LineConfig::config3(), 3.0, 1.0);
    let mut sa = Subarray::new(design);
    let run = layer.run_batch(&mut sa, &patches, TmvmMode::Ideal);
    for (pos, out) in run.outputs.iter().enumerate() {
        for (f, &bit) in out.iter().enumerate() {
            assert_eq!(bit, direct[f][pos], "filter {f} pos {pos}");
        }
    }
}

#[test]
fn batch_energy_scales_with_batch_not_array() {
    // energy per image is batch-size and array-size independent (Table II)
    let layer = template_layer();
    let mut gen = DigitGen::new(5);
    let images: Vec<Vec<bool>> = (0..32).map(|_| gen.next_sample().pixels).collect();
    let mut energies = vec![];
    for n_row in [64usize, 256] {
        let design = ArrayDesign::new(n_row, 128, LineConfig::config3(), 3.0, 1.0);
        let mut sa = Subarray::new(design);
        let run = layer.run_batch(&mut sa, &images, TmvmMode::Ideal);
        let step_e: f64 = run.steps.iter().map(|s| s.energy).sum();
        energies.push(step_e / images.len() as f64);
    }
    let ratio = energies[1] / energies[0];
    assert!(
        (0.99..1.01).contains(&ratio),
        "energy/image must not depend on array size: {ratio}"
    );
}

//! [`FabricBackend`] — plugs a whole simulated fabric into the L3
//! coordinator, so the serving shell can drive a grid of subarrays
//! exactly like it drives a single one.

use super::exec::{FabricExecutor, FabricRun};
use super::placement::FabricConfig;
use crate::coordinator::{Backend, InferenceResult};
use crate::nn::{argmax_counts, BinaryLayer};

/// Coordinator backend running batches through a [`FabricExecutor`].
pub struct FabricBackend {
    exec: FabricExecutor,
    max_batch: usize,
    /// Cumulative simulated busy time across batches \[s\].
    pub total_sim_time: f64,
    /// Cumulative energy across batches \[J\].
    pub total_energy: f64,
}

impl FabricBackend {
    /// Place `layers` on the fabric described by `cfg`. `max_batch` caps
    /// the images accepted per `infer_batch` call (the pipeline itself has
    /// no hard limit; the cap bounds per-batch simulation memory).
    pub fn new(
        layers: Vec<BinaryLayer>,
        cfg: FabricConfig,
        max_batch: usize,
    ) -> crate::Result<Self> {
        anyhow::ensure!(max_batch >= 1, "max_batch must be positive");
        Ok(Self {
            exec: FabricExecutor::new(layers, cfg)?,
            max_batch,
            total_sim_time: 0.0,
            total_energy: 0.0,
        })
    }

    pub fn executor(&self) -> &FabricExecutor {
        &self.exec
    }

    /// The last run's argmax classes from fabric-accumulated counts
    /// (shared first-max-wins tie-break with [`BinaryLayer::argmax`]).
    fn classes(&self, run: &FabricRun) -> Vec<usize> {
        run.final_counts
            .iter()
            .map(|counts| argmax_counts(counts))
            .collect()
    }
}

impl Backend for FabricBackend {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        anyhow::ensure!(
            images.len() <= self.max_batch,
            "batch of {} exceeds fabric max_batch {}",
            images.len(),
            self.max_batch
        );
        let run = self.exec.run_batch(images)?;
        let classes = self.classes(&run);
        self.total_sim_time += run.makespan;
        self.total_energy += run.energy;
        Ok(InferenceResult {
            bits: run.outputs,
            classes,
            sim_time: run.makespan,
            energy: run.energy,
            steps: run.steps,
        })
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ArrayDesign;
    use crate::array::TmvmMode;
    use crate::coordinator::SimBackend;
    use crate::interconnect::LineConfig;
    use crate::util::Pcg32;

    /// A fabric hosting a single tiled layer must agree with the
    /// single-subarray `SimBackend` on bits, classes — and on compute
    /// energy (the step decompositions differ, weights-applied vs
    /// weights-stored, but the summed Eq. 3 currents are identical).
    #[test]
    fn fabric_backend_matches_sim_backend() {
        let mut rng = Pcg32::seeded(61);
        let layer = BinaryLayer::new(
            (0..10)
                .map(|_| (0..40).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            4,
        );
        let images: Vec<Vec<bool>> = (0..12)
            .map(|_| (0..40).map(|_| rng.bernoulli(0.4)).collect())
            .collect();

        let design = ArrayDesign::new(16, 64, LineConfig::config3(), 3.0, 1.0);
        let mut sim = SimBackend::new(layer.clone(), design, TmvmMode::Ideal);
        let sim_res = sim.infer_batch(&images).unwrap();

        // untiled fabric (layer fits one subarray): bits and classes agree
        // exactly, and compute energy agrees to sub-percent — the crystalline
        // current terms are identical whether steps sweep neurons
        // (SimBackend, images stored / weights applied) or images (fabric,
        // weights stored / images applied); only the tiny G_A leakage term
        // differs between the two orientations.
        let mut fab1 =
            FabricBackend::new(vec![layer.clone()], FabricConfig::new(1, 1, 16, 64), 64).unwrap();
        let res1 = fab1.infer_batch(&images).unwrap();
        assert_eq!(res1.bits, sim_res.bits);
        assert_eq!(res1.classes, sim_res.classes);
        let run1 = fab1.executor().run_batch(&images).unwrap();
        let rel = (run1.compute_energy - sim_res.energy).abs() / sim_res.energy;
        assert!(
            rel < 0.01,
            "compute energy drift: fabric {} vs sim {}",
            run1.compute_energy,
            sim_res.energy
        );

        // column-tiled fabric (40 cols over 16-wide tiles → 3 tiles):
        // still bit-exact; compute energy is ≥ the flat value because each
        // tile's local current I(c) = G_C·V·c/(c+1) is concave in c —
        // partial paths book more than the merged path would
        let mut fab3 =
            FabricBackend::new(vec![layer], FabricConfig::new(2, 2, 16, 16), 64).unwrap();
        let res3 = fab3.infer_batch(&images).unwrap();
        assert_eq!(res3.bits, sim_res.bits);
        assert_eq!(res3.classes, sim_res.classes);
        let run3 = fab3.executor().run_batch(&images).unwrap();
        assert!(run3.compute_energy >= sim_res.energy * (1.0 - 1e-12));
        assert!(run3.link_energy > 0.0, "partials crossed the fabric");
        assert!(res3.sim_time > 0.0);
        assert!(res3.steps >= sim_res.steps, "tiled steps ≥ per-neuron steps");
    }

    #[test]
    fn oversized_batch_rejected() {
        let mut rng = Pcg32::seeded(62);
        let layer = BinaryLayer::new(
            (0..4)
                .map(|_| (0..8).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            2,
        );
        let mut fab =
            FabricBackend::new(vec![layer], FabricConfig::new(1, 1, 8, 8), 2).unwrap();
        let images: Vec<Vec<bool>> = (0..3).map(|_| vec![true; 8]).collect();
        assert!(fab.infer_batch(&images).is_err());
    }
}

//! SI-unit formatting for human-readable reports: `1.5e-5 A` → `"15.0µA"`.

const PREFIXES: &[(f64, &str)] = &[
    (1e12, "T"),
    (1e9, "G"),
    (1e6, "M"),
    (1e3, "k"),
    (1.0, ""),
    (1e-3, "m"),
    (1e-6, "µ"),
    (1e-9, "n"),
    (1e-12, "p"),
    (1e-15, "f"),
];

/// Format `value` with an SI prefix and the given unit, 3 significant-ish
/// digits (`format_si(2.15e-11, "J") == "21.5pJ"`).
pub fn format_si(value: f64, unit: &str) -> String {
    if value == 0.0 {
        return format!("0{unit}");
    }
    if !value.is_finite() {
        return format!("{value}{unit}");
    }
    let mag = value.abs();
    for &(scale, prefix) in PREFIXES {
        if mag >= scale {
            let scaled = value / scale;
            return if scaled.abs() >= 100.0 {
                format!("{scaled:.0}{prefix}{unit}")
            } else if scaled.abs() >= 10.0 {
                format!("{scaled:.1}{prefix}{unit}")
            } else {
                format!("{scaled:.2}{prefix}{unit}")
            };
        }
    }
    format!("{value:.3e}{unit}")
}

/// Format seconds as an adaptive duration (`80e-9` → `"80.0ns"`).
pub fn format_duration(seconds: f64) -> String {
    format_si(seconds, "s")
}

/// Format a ratio as a percentage with one decimal (`0.651` → `"65.1%"`).
pub fn format_pct(ratio: f64) -> String {
    format!("{:.1}%", ratio * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_currents() {
        assert_eq!(format_si(50e-6, "A"), "50.0µA");
        assert_eq!(format_si(100e-6, "A"), "100µA");
        assert_eq!(format_si(1.5e-3, "A"), "1.50mA");
    }

    #[test]
    fn formats_energy_and_time() {
        assert_eq!(format_si(21.5e-12, "J"), "21.5pJ");
        assert_eq!(format_duration(80e-9), "80.0ns");
        assert_eq!(format_duration(133.3e-6), "133µs");
    }

    #[test]
    fn formats_edge_cases() {
        assert_eq!(format_si(0.0, "V"), "0V");
        assert_eq!(format_pct(0.345), "34.5%");
        assert_eq!(format_si(-0.31, "V"), "-310mV");
    }
}

//! Every paper exhibit as a library function returning structured rows —
//! shared by `cargo bench`, the examples and the CLI so the numbers are
//! generated from exactly one code path.

pub mod autoscale;
pub mod exhibits;
pub mod fabric;
pub mod montecarlo;
pub mod reprogram;
pub mod sharding;
pub mod table2;

pub use autoscale::{
    autoscale_json, autoscale_summary_line, autoscale_table, autoscale_timeline,
    autoscale_timeline_trace, AutoscaleSummary, AutoscaleWaveRow, AUTOSCALE_MAX, AUTOSCALE_MIN,
    AUTOSCALE_TRACE,
};
pub use exhibits::{
    fig10_series, fig11_regions, fig13_sweeps, table1_rows, table3_rows, Fig10Row, Fig11Data,
    Fig13Series,
};
pub use fabric::{fabric_scaling_rows, fabric_scaling_table, FabricScalingRow, FABRIC_GRIDS};
pub use montecarlo::{
    montecarlo_json, montecarlo_rows, montecarlo_summary_line, montecarlo_table, MC_SEED,
    MC_TRIALS,
};
pub use reprogram::{
    perturbed_workload, reprogram_summary, reprogram_table, reprogram_timeline,
    ReprogramWaveRow, REPROGRAM_SHARDS, REPROGRAM_WAVES,
};
pub use sharding::{shard_scaling_rows, shard_scaling_table, ShardScalingRow, SHARD_SWEEP};
pub use table2::{table2_rows, Table2Row, TABLE2_DESIGNS};
